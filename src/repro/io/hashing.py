"""Signed feature hashing into a fixed, tile-aligned feature space.

The paper's motivating text/clickstream workloads have unbounded
vocabularies — new tokens appear mid-stream, so a vocabulary pass (token →
dense column id) is both a second scan over the data and a stale artifact
the moment traffic shifts.  Feature hashing (Weinberger et al. 2009)
removes the vocabulary entirely: feature key ``k`` with value ``v``
contributes ``sign(k) * v`` to column ``bucket(k)`` of a FIXED
``n_features``-dimensional space.  Collisions become signed sums, so the
expected inner product between hashed vectors is unbiased — the signature
property tested in ``tests/test_io.py``.

Two properties matter for this repo specifically:

  * **determinism across processes** — the hash is our own splitmix64 /
    FNV-1a mix over the key bytes, never Python's randomized ``hash``, so
    every process of a distributed job (and every resumed run) maps the
    same token to the same column.  ``StreamingDesign.process_slice`` and
    the brick packers both assume column ids are process-invariant.
  * **tile alignment** — ``n_features`` is rounded UP to a multiple of
    ``tile_size * n_shards``, so hashed chunks drop straight into the
    existing layouts: tile ``t = col // T`` of the streaming chunk, or
    brick ``(row_block, t)`` of ``BlockSparseDesign``, with no padding
    remap.  The hashing-to-bricks mapping is the identity on the hashed
    column space (DESIGN.md §10).

``expand_interactions`` adds on-the-fly sparse feature crosses (the
clickstream idiom: ``user_segment × ad_slot``): every unordered pair of
raw keys present in a row is hashed — through the same signed hash, in a
disjoint salt space — to a new column whose value is the product of the
paired values.  No cross is ever materialized on disk.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

_MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)
_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 finalizer — a full-avalanche 64-bit mix
    (Steele et al.), the integer-key workhorse behind the hasher."""
    x = np.asarray(x, np.uint64)
    with np.errstate(over="ignore"):
        x = (x + np.uint64(0x9E3779B97F4A7C15)) & _MASK64
        x = ((x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) \
            & _MASK64
        x = ((x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) \
            & _MASK64
        return x ^ (x >> np.uint64(31))


def fnv1a64(data: bytes) -> int:
    """FNV-1a over raw bytes — the stable string-key hash (Python's
    ``hash(str)`` is salted per process and would break cross-process
    column agreement)."""
    h = int(_FNV_OFFSET)
    for b in data:
        h = ((h ^ b) * int(_FNV_PRIME)) & 0xFFFFFFFFFFFFFFFF
    return h


class FeatureHasher:
    """Signed hash of feature keys into ``n_features`` tile-aligned buckets.

    ``n_features`` is rounded up to a multiple of ``tile_size * n_shards``
    (both optional) and exposed as the ``n_features`` attribute — build
    the downstream design from that.  ``seed`` salts the whole map;
    ``field`` salts per key-namespace (e.g. raw features vs interaction
    crosses live in disjoint salt spaces even when their integer keys
    collide).
    """

    def __init__(self, n_features: int, *, tile_size: Optional[int] = None,
                 n_shards: int = 1, seed: int = 0):
        if n_features <= 0:
            raise ValueError("n_features must be positive")
        align = (tile_size or 1) * max(int(n_shards), 1)
        self.n_features = int(n_features) + (-int(n_features)) % align
        self.tile_size = tile_size
        self.seed = int(seed)
        self._salt = splitmix64(
            np.asarray([self.seed], np.uint64))[0]

    # ------------------------------------------------------------- hashing

    def _mix(self, keys: np.ndarray, field: int) -> np.ndarray:
        field_salt = splitmix64(
            np.asarray([field ^ 0x5851F42D], np.uint64))[0]
        with np.errstate(over="ignore"):
            return splitmix64(
                (np.asarray(keys, np.uint64) ^ self._salt) + field_salt)

    def hash_indices(self, keys, field: int = 0):
        """(cols (m,) int64, signs (m,) float32) for integer feature keys.

        The top hash bit gives the ±1 sign; the rest pick the bucket —
        sign and bucket are independent, which the unbiasedness argument
        needs.
        """
        h = self._mix(np.asarray(keys, np.uint64), field)
        cols = (h % np.uint64(self.n_features)).astype(np.int64)
        signs = np.where((h >> np.uint64(63)).astype(bool),
                         np.float32(1.0), np.float32(-1.0))
        return cols, signs

    def hash_tokens(self, tokens: Sequence[str], field: int = 0):
        """(cols, signs) for string tokens — FNV-1a bytes → splitmix mix,
        stable across processes and Python versions."""
        keys = np.asarray(
            [fnv1a64(t.encode("utf-8")) for t in tokens], np.uint64)
        return self.hash_indices(keys, field)

    # -------------------------------------------------------- chunk mapping

    def transform_chunk(self, cols: np.ndarray, vals: np.ndarray,
                        *, field: int = 0,
                        interactions: int = 0) -> np.ndarray:
        """Dense hashed chunk from fixed-shape padded sparse rows.

        ``cols``/``vals`` are ``(rows, width)`` with padding marked by
        ``cols < 0`` (the reader chunk layout).  Returns the dense
        ``(rows, n_features)`` float32 chunk: each valid entry adds
        ``sign * val`` into its bucket; with ``interactions=k > 0`` every
        unordered pair among the first ``k`` valid keys of each row adds
        a hashed cross (value = product) on top.
        """
        cols = np.asarray(cols)
        vals = np.asarray(vals, np.float32)
        rows, width = cols.shape
        out = np.zeros((rows, self.n_features), np.float32)
        valid = cols >= 0
        r_idx, c_idx = np.nonzero(valid)
        if len(r_idx):
            hcols, signs = self.hash_indices(
                cols[r_idx, c_idx].astype(np.uint64), field)
            np.add.at(out, (r_idx, hcols), signs * vals[r_idx, c_idx])
        if interactions > 0:
            ic, iv = expand_interactions(cols, vals, self,
                                         max_keys=interactions)
            ir, ij = np.nonzero(ic >= 0)
            if len(ir):
                np.add.at(out, (ir, ic[ir, ij]), iv[ir, ij])
        return out


def expand_interactions(cols: np.ndarray, vals: np.ndarray,
                        hasher: FeatureHasher, *, max_keys: int = 8,
                        field: int = 1):
    """Hashed unordered feature crosses for every row of a padded sparse
    chunk.

    For each row, the first ``max_keys`` valid raw keys generate all
    ``C(k, 2)`` pairs; pair ``(a, b)`` (order-normalized so ``a ≤ b``)
    hashes — in salt space ``field``, disjoint from the raw features — to
    a signed bucket with value ``v_a · v_b``.  Returns ``(icols, ivals)``
    of shape ``(rows, C(max_keys, 2))`` with ``icols < 0`` marking
    padding, i.e. the same fixed-shape sparse chunk layout as the input.
    """
    cols = np.asarray(cols)
    vals = np.asarray(vals, np.float32)
    rows = cols.shape[0]
    k = min(int(max_keys), cols.shape[1])
    ia, ib = np.triu_indices(k, k=1)
    n_pairs = len(ia)
    icols = np.full((rows, n_pairs), -1, np.int64)
    ivals = np.zeros((rows, n_pairs), np.float32)
    if n_pairs == 0:
        return icols, ivals
    ca, cb = cols[:, :k][:, ia], cols[:, :k][:, ib]
    va, vb = vals[:, :k][:, ia], vals[:, :k][:, ib]
    valid = (ca >= 0) & (cb >= 0)
    lo = np.minimum(ca, cb).astype(np.uint64)
    hi = np.maximum(ca, cb).astype(np.uint64)
    # injective-ish unordered pair key: mix lo before combining with hi so
    # (1, 23) and (12, 3)-style concatenation aliases cannot happen
    with np.errstate(over="ignore"):
        pair_key = splitmix64(lo) ^ (hi + np.uint64(0x9E3779B9))
    hcols, signs = hasher.hash_indices(pair_key.reshape(-1), field)
    hcols = hcols.reshape(rows, n_pairs)
    signs = signs.reshape(rows, n_pairs)
    icols[valid] = hcols[valid]
    ivals[valid] = (signs * va * vb)[valid]
    return icols, ivals
