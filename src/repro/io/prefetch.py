"""Bounded background prefetch queue over any chunk callable.

``StreamingDesign.iter_chunks`` already double-buffers the host→device
COPY, but for file-backed sources the expensive part is chunk PRODUCTION
— parsing libsvm text, decompressing gzip, decoding Parquet pages.  That
work happens on the Python side and serializes with device compute unless
someone moves it off the consumer thread.

``PrefetchingSource`` is that someone: a worker thread walks the chunk
indices in order, calls the wrapped ``chunk_fn``, and parks results in a
bounded queue (the tf.data ``prefetch()`` idiom, translated to
``threading.Thread`` + ``queue.Queue``).  While XLA executes a chunk's
compute — which releases the GIL — the worker parses the next chunk, so
reader throughput and device throughput overlap instead of adding
(``benchmarks/ingest_bench.py`` measures the resulting >1× speedup).

Semantics:

  * the wrapper IS a chunk callable — ``source(i)`` returns exactly
    ``chunk_fn(i)`` — so it composes with ``StreamingDesign`` untouched;
  * the queue is bounded (``depth``), so production never runs more than
    ``depth`` chunks ahead of consumption: host memory stays at
    ``depth × chunk_bytes`` no matter how slow the consumer is;
  * sequential access (the solver's passes) streams through the queue; a
    NON-sequential request (resume from a checkpointed chunk cursor,
    pass restarts) drains the worker and restarts it at the requested
    index — correctness never depends on the access pattern;
  * worker exceptions are re-raised in the consumer at the offending
    index, not swallowed;
  * ``close()`` (or ``with`` exit) stops the worker; a dropped source is
    also closed by its finalizer, so abandoned iterations cannot leak a
    thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


class PrefetchingSource:
    """Chunk callable that produces ``depth`` chunks ahead on a thread.

    Args:
      chunk_fn: the wrapped producer, a pure function of the chunk index
        (the ``data/pipeline.py`` contract — purity is what makes the
        restart-on-seek path exact).
      n_chunks: total chunks; the worker stops after the last one.
      depth: queue bound — how many produced-but-unconsumed chunks may
        exist at once (2 is classic double buffering).
    """

    def __init__(self, chunk_fn: Callable, n_chunks: int, *,
                 depth: int = 2):
        if depth <= 0:
            raise ValueError("depth must be positive")
        self._fn = chunk_fn
        self.n_chunks = int(n_chunks)
        self.depth = int(depth)
        self._q: Optional[queue.Queue] = None
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._next = None           # index the queue head will hold

    # ------------------------------------------------------------- worker

    def _run(self, start: int, q: queue.Queue, stop: threading.Event):
        depth_gauge = obs_metrics.gauge("io.prefetch.queue_depth")
        for i in range(start, self.n_chunks):
            if stop.is_set():
                return
            try:
                # the span puts chunk production on the worker thread's
                # own trace lane — overlap with the consumer's device
                # compute is visible directly in Perfetto
                with obs_trace.span("io/prefetch_produce",
                                    args={"chunk": i}):
                    item = (i, self._fn(i), None)
            except BaseException as e:          # re-raised at the consumer
                item = (i, None, e)
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.1)
                    depth_gauge.set(q.qsize())
                    break
                except queue.Full:
                    continue
            if item[2] is not None:
                return

    def _restart(self, start: int):
        self._shutdown()
        self._stop = threading.Event()
        self._q = queue.Queue(maxsize=self.depth)
        self._next = start
        self._worker = threading.Thread(
            target=self._run, args=(start, self._q, self._stop),
            name="repro-io-prefetch", daemon=True)
        self._worker.start()

    def _shutdown(self):
        if self._worker is not None:
            self._stop.set()
            while True:             # unblock a producer stuck on put()
                try:
                    self._q.get_nowait()
                except queue.Empty:
                    break
            self._worker.join()
            self._worker = None
        self._q = None
        self._next = None

    # ----------------------------------------------------------- consumer

    def __call__(self, i: int):
        if not 0 <= i < self.n_chunks:
            raise IndexError(f"chunk {i} out of range ({self.n_chunks})")
        if self._next != i or self._q is None:
            self._restart(i)        # non-sequential: reseek the stream
        got, chunk, err = self._q.get()
        self._next = i + 1 if i + 1 < self.n_chunks else None
        if err is not None:
            self._shutdown()
            raise err
        assert got == i, f"prefetch stream desync: wanted {i}, got {got}"
        return chunk

    def close(self):
        """Stop the worker and drop queued chunks (idempotent)."""
        self._shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass
