"""Chunked libsvm/svmlight reader and writer (DESIGN.md §10).

The libsvm text format (``label idx:val idx:val ...``, one row per line,
optionally gzip-compressed) is the lingua franca of the sparse-GLM
benchmark datasets the paper and its comparison line evaluate on.  This
reader turns such a file into the ``data/pipeline.py`` chunk-callable
contract without ever materializing the full matrix:

  * **pass 1 (scan)** counts rows, the max feature index, the max row nnz,
    and collects the label vector (n floats — the one thing small enough
    to keep); for PLAIN files it also records the byte offset of every
    chunk boundary, making ``chunk(i)`` an O(1) seek.  Gzip streams are
    not seekable, so gz files use a sequential cursor instead: reading
    chunks in order costs one decompression pass per epoch, and a
    random-access request falls back to reopen-and-skip (correct, just
    slower — the solver's passes are sequential, so this path only runs
    on resume).
  * **capped-dimension single-pass mode**: pass ``n_rows``/``n_features``
    (and ``max_nnz`` if sparse chunks are consumed) explicitly and the
    scan is skipped entirely — the streaming-from-a-live-pipe shape.

Chunks come out in two forms sharing one parse:

  * ``chunk(i)`` — fixed-shape PADDED SPARSE ``(rows_i, max_nnz)`` pairs
    ``(cols, vals)`` with ``cols < 0`` marking padding: the layout
    ``io/hashing.py`` consumes;
  * ``chunk_fn(i)`` / ``hashed_chunk_fn(hasher)(i)`` — dense
    ``(rows_i, p)`` rows satisfying the chunk contract, either exact
    features or the hashed feature space.

``to_design`` wires straight into ``StreamingDesign`` (optionally through
``io/prefetch.py``'s background queue); ``to_coo`` materializes a
``SparseCOO`` for in-memory fits (the parity baseline in tests).
"""
from __future__ import annotations

import gzip
import pathlib
import threading
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.data.sparse import SparseCOO
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def _open(path, mode="rt"):
    path = pathlib.Path(path)
    if path.suffix == ".gz":
        return gzip.open(path, mode)
    return open(path, mode)


def parse_line(line: str):
    """(label, idx int64[], val f32[]) for one libsvm line; None for blank
    or comment lines.  ``qid:...`` ranking annotations are skipped."""
    hash_pos = line.find("#")
    if hash_pos >= 0:
        line = line[:hash_pos]
    parts = line.split()
    if not parts:
        return None
    label = float(parts[0])
    idx, vals = [], []
    for tok in parts[1:]:
        k, _, v = tok.partition(":")
        if k == "qid":
            continue
        idx.append(int(k))
        vals.append(float(v))
    return label, np.asarray(idx, np.int64), np.asarray(vals, np.float32)


def write_libsvm(path, X, y, *, zero_based: bool = True,
                 precision: int = 9) -> pathlib.Path:
    """Write (X, y) as libsvm text; gzip when ``path`` ends in ``.gz``.

    ``X`` is a ``SparseCOO`` or a dense array (zeros are dropped).
    ``zero_based=False`` writes 1-based feature indices (the classic
    libsvm convention; the reader auto-detects either).  The default
    ``precision`` of 9 significant digits round-trips float32 EXACTLY
    (%.9g), which is what the file-vs-memory parity tests lean on; drop
    to 7 for smaller files when bit-exactness does not matter."""
    path = pathlib.Path(path)
    if isinstance(X, SparseCOO):
        coo = X.dedupe()
        n = coo.shape[0]
        order = np.lexsort((coo.cols, coo.rows))
        rows, cols, vals = coo.rows[order], coo.cols[order], coo.vals[order]
        starts = np.searchsorted(rows, np.arange(n + 1))
    else:
        Xd = np.asarray(X, np.float32)
        n = Xd.shape[0]
    y = np.asarray(y)
    off = 0 if zero_based else 1
    fmt = f"%d:%.{precision}g"
    with _open(path, "wt") as f:
        for i in range(n):
            if isinstance(X, SparseCOO):
                lo, hi = starts[i], starts[i + 1]
                feats = " ".join(fmt % (cols[j] + off, vals[j])
                                 for j in range(lo, hi))
            else:
                nz = np.nonzero(Xd[i])[0]
                feats = " ".join(fmt % (j + off, Xd[i, j]) for j in nz)
            f.write(f"%.{precision}g {feats}\n" % y[i]
                    if feats else f"%.{precision}g\n" % y[i])
    return path


class LibsvmReader:
    """Chunked reader over one libsvm(.gz) file.

    Args:
      path: the file; ``.gz`` suffix switches to the gzip codec.
      chunk_rows: rows per chunk (the last chunk is ragged — the chunk
        contract).
      n_rows / n_features / max_nnz: supply ALL of ``n_rows`` +
        ``n_features`` to skip the scan (single-pass mode; ``labels()``
        then triggers a lazy scan on first use).  ``n_features`` also acts
        as a cap: exact-feature chunks raise on indices beyond it (a
        hashed pipeline never hits this — it hashes raw indices).
      zero_based: index convention; None auto-detects from the scan
        (min index 0 → zero-based; pure single-pass mode defaults to
        zero-based).
      cache_chunks: retain up to this many PARSED chunks (the padded
        (cols, vals) triplet form, far smaller than the dense chunk) in
        an LRU, so the solver's repeated passes — two per superstep,
        every superstep — skip the gzip + text parse after the first
        epoch.  Host memory stays bounded at roughly
        ``cache_chunks × chunk_rows × max_nnz × 12`` bytes; 0 (default)
        reparses every pass (the strict out-of-core mode).
    """

    def __init__(self, path, *, chunk_rows: int = 4096,
                 n_rows: Optional[int] = None,
                 n_features: Optional[int] = None,
                 max_nnz: Optional[int] = None,
                 zero_based: Optional[bool] = None,
                 cache_chunks: int = 0):
        self.path = pathlib.Path(path)
        if chunk_rows <= 0:
            raise ValueError("chunk_rows must be positive")
        self.chunk_rows = int(chunk_rows)
        self._zero_based = zero_based
        self._labels: Optional[np.ndarray] = None
        self._offsets: Optional[np.ndarray] = None  # plain files only
        self._gz = self.path.suffix == ".gz"
        self._cursor = None          # (open handle, next row index)
        self._lock = threading.Lock()
        self.cache_chunks = int(cache_chunks)
        self._cache: "OrderedDict" = OrderedDict()
        if n_rows is None or n_features is None:
            self._scan()
            if n_features is not None:
                if self.n_features > n_features:
                    raise ValueError(
                        f"{self.path} has features up to "
                        f"{self.n_features - 1}; cap n_features="
                        f"{n_features} is too small")
                self.n_features = int(n_features)
            if n_rows is not None and n_rows != self.n_rows:
                raise ValueError(
                    f"{self.path} has {self.n_rows} rows, not {n_rows}")
            if max_nnz is not None:
                self.max_nnz = max(int(max_nnz), self.max_nnz)
        else:
            self.n_rows = int(n_rows)
            self.n_features = int(n_features)
            self.max_nnz = 0 if max_nnz is None else int(max_nnz)
            if self._zero_based is None:
                self._zero_based = True
        if self.n_rows <= 0:
            raise ValueError(f"{self.path} has no data rows")
        self.n_chunks = -(-self.n_rows // self.chunk_rows)

    # ------------------------------------------------------------ pass 1

    def _scan(self):
        """One sequential pass: row count, label vector, max feature
        index, max nnz, and (plain files) chunk-boundary byte offsets."""
        labels, offsets = [], []
        max_idx, min_idx, max_nnz = -1, None, 0
        with _open(self.path, "rt") as f:
            while True:
                if not self._gz and len(labels) % self.chunk_rows == 0:
                    offsets.append(f.tell())
                line = f.readline()
                if not line:
                    break
                parsed = parse_line(line)
                if parsed is None:
                    continue
                label, idx, _ = parsed
                labels.append(label)
                if len(idx):
                    max_idx = max(max_idx, int(idx.max()))
                    lo = int(idx.min())
                    min_idx = lo if min_idx is None else min(min_idx, lo)
                    max_nnz = max(max_nnz, len(idx))
        if self._zero_based is None:
            self._zero_based = (min_idx == 0) if min_idx is not None \
                else True
        self.n_rows = len(labels)
        shift = 0 if self._zero_based else 1
        self.n_features = max(max_idx + 1 - shift, 1)
        self.max_nnz = max(max_nnz, 1)
        self._labels = np.asarray(labels, np.float32)
        if not self._gz:
            self._offsets = np.asarray(
                offsets[:-(-self.n_rows // self.chunk_rows)], np.int64) \
                if labels else np.zeros((0,), np.int64)

    def labels(self) -> np.ndarray:
        """(n_rows,) float32 label vector (lazy scan in single-pass
        mode)."""
        if self._labels is None:
            keep = (self.n_rows, self.n_features, self.max_nnz)
            self._scan()
            self.n_rows, self.n_features, self.max_nnz = keep
        return self._labels

    # ---------------------------------------------------------- raw rows

    def _read_lines(self, i: int):
        """The parsed rows of chunk ``i`` — O(1) seek on plain files,
        sequential cursor (restart on backward jumps) on gzip."""
        lo = i * self.chunk_rows
        rows = min(self.chunk_rows, self.n_rows - lo)
        if rows <= 0:
            raise IndexError(f"chunk {i} out of range ({self.n_chunks})")
        out = []
        with self._lock:
            if self._offsets is not None and i < len(self._offsets):
                f = _open(self.path, "rt")
                f.seek(int(self._offsets[i]))
                at = lo
            else:
                if self._cursor is not None and self._cursor[1] == lo:
                    f, at = self._cursor
                else:
                    if self._cursor is not None:
                        self._cursor[0].close()
                    f, at = _open(self.path, "rt"), 0
                while at < lo:                    # forward skip
                    if parse_line(f.readline()) is not None:
                        at += 1
            while len(out) < rows:
                parsed = parse_line(f.readline())
                if parsed is not None:
                    out.append(parsed)
                    at += 1
            if self._offsets is not None:
                f.close()
            else:
                self._cursor = [f, at] if at < self.n_rows else None
                if at >= self.n_rows:
                    f.close()
        return out

    def chunk(self, i: int):
        """Fixed-shape padded sparse chunk ``i``: ``(cols, vals)`` of
        shape ``(rows_i, max_nnz)`` with ``cols < 0`` marking padding —
        raw (unshifted-to-cap) indices, the hashing input layout.

        With ``cache_chunks > 0`` parsed chunks are served from a bounded
        LRU (copy-free: callers never mutate them), so only the first
        epoch pays the decompress+parse cost."""
        if self.cache_chunks > 0:
            with self._lock:
                hit = self._cache.get(i)
                if hit is not None:
                    self._cache.move_to_end(i)
                    obs_metrics.counter("io.chunk_cache.hit").inc()
                    return hit
            obs_metrics.counter("io.chunk_cache.miss").inc()
        with obs_trace.span("io/parse_chunk", args={"chunk": i}):
            lines = self._read_lines(i)
            width = max(self.max_nnz, max((len(ix) for _, ix, _ in lines),
                                          default=1), 1)
            cols = np.full((len(lines), width), -1, np.int64)
            vals = np.zeros((len(lines), width), np.float32)
            shift = 0 if self._zero_based else 1
            for r, (_, idx, v) in enumerate(lines):
                cols[r, :len(idx)] = idx - shift
                vals[r, :len(idx)] = v
        if self.cache_chunks > 0:
            with self._lock:
                self._cache[i] = (cols, vals)
                self._cache.move_to_end(i)
                while len(self._cache) > self.cache_chunks:
                    self._cache.popitem(last=False)
        return cols, vals

    def chunk_fn(self, i: int) -> np.ndarray:
        """Dense exact-feature chunk ``(rows_i, n_features)`` — the chunk
        contract for vocabulary-bounded data."""
        cols, vals = self.chunk(i)
        out = np.zeros((cols.shape[0], self.n_features), np.float32)
        r, c = np.nonzero(cols >= 0)
        if len(r):
            j = cols[r, c]
            if j.max(initial=-1) >= self.n_features:
                raise ValueError(
                    f"chunk {i} has feature index {int(j.max())} beyond "
                    f"the n_features={self.n_features} cap; raise the cap "
                    "or hash the features (io.hashing)")
            np.add.at(out, (r, j), vals[r, c])
        return out

    def hashed_chunk_fn(self, hasher, *, interactions: int = 0):
        """Chunk callable in the hashed feature space
        ``(rows_i, hasher.n_features)`` — unbounded vocabularies stream
        into a fixed layout, optionally with on-the-fly crosses."""
        def fn(i: int, _r=self, _h=hasher, _k=int(interactions)):
            cols, vals = _r.chunk(i)
            return _h.transform_chunk(cols, vals, interactions=_k)
        return fn

    # ------------------------------------------------------- integrations

    def to_coo(self) -> SparseCOO:
        """Whole-file SparseCOO (exact features) — the in-memory parity
        baseline; only call on data that fits in host memory."""
        rows, cols, vals = [], [], []
        for i in range(self.n_chunks):
            c, v = self.chunk(i)
            r, j = np.nonzero(c >= 0)
            rows.append(r + i * self.chunk_rows)
            cols.append(c[r, j])
            vals.append(v[r, j])
        return SparseCOO(np.concatenate(rows), np.concatenate(cols),
                         np.concatenate(vals).astype(np.float32),
                         (self.n_rows, self.n_features)).dedupe()

    def to_design(self, tile_size: int, *, hasher=None,
                  interactions: int = 0, prefetch: bool = True,
                  prefetch_chunks: int = 0):
        """``StreamingDesign`` over this file (DESIGN.md §6/§10).

        ``hasher`` switches to the hashed feature space (+ optional
        interaction crosses); ``prefetch_chunks > 0`` wraps the chunk
        callable in ``io.prefetch.PrefetchingSource`` so chunk parsing
        runs in a background thread that deep; ``prefetch`` controls the
        design's own host→device double buffering.
        """
        from repro.data.design import StreamingDesign
        if hasher is not None:
            fn = self.hashed_chunk_fn(hasher, interactions=interactions)
            n_cols = hasher.n_features
        else:
            fn, n_cols = self.chunk_fn, self.n_features
        if prefetch_chunks > 0:
            from repro.io.prefetch import PrefetchingSource
            fn = PrefetchingSource(fn, self.n_chunks,
                                   depth=prefetch_chunks)
        return StreamingDesign(fn, n_rows=self.n_rows, n_cols=n_cols,
                               chunk_rows=self.chunk_rows,
                               tile_size=tile_size, prefetch=prefetch)
