"""repro.io — the ingestion layer between raw bytes and the operator
abstraction (DESIGN.md §10).

Readers (``LibsvmReader``, ``ParquetReader``) turn on-disk data into the
``data/pipeline.py`` chunk-callable contract; ``FeatureHasher`` maps
unbounded vocabularies into a fixed tile-aligned feature space;
``PrefetchingSource`` overlaps chunk production with device compute.
``open_reader``/``open_design`` are the one-call front door the solver
and estimators use to accept a path where they accept a matrix.
"""
from __future__ import annotations

import pathlib

from repro.io.hashing import FeatureHasher, expand_interactions
from repro.io.libsvm import LibsvmReader, write_libsvm
from repro.io.parquet import HAVE_PYARROW, ParquetReader
from repro.io.prefetch import PrefetchingSource

__all__ = [
    "FeatureHasher", "expand_interactions", "LibsvmReader", "write_libsvm",
    "ParquetReader", "HAVE_PYARROW", "PrefetchingSource",
    "open_reader", "open_design", "is_reader",
]

_PARQUET_SUFFIXES = (".parquet", ".pq")


def is_reader(obj) -> bool:
    """Duck-typed reader check: anything with the reader surface
    (``chunk_fn``/``labels``/``to_design``) counts, so third-party
    sources integrate without subclassing."""
    return all(hasattr(obj, a) for a in ("chunk_fn", "labels",
                                         "to_design"))


def open_reader(path, *, chunk_rows: int = 4096, **kwargs):
    """Reader for ``path``, dispatched on suffix: ``.parquet``/``.pq`` →
    ``ParquetReader``, everything else (``.libsvm``, ``.svm``, ``.txt``,
    optionally ``.gz``-compressed) → ``LibsvmReader``."""
    p = pathlib.Path(path)
    suffixes = [s.lower() for s in p.suffixes]
    if suffixes and suffixes[-1] in _PARQUET_SUFFIXES:
        return ParquetReader(p, chunk_rows=chunk_rows, **kwargs)
    return LibsvmReader(p, chunk_rows=chunk_rows, **kwargs)


def open_design(source, *, tile_size: int, chunk_rows: int = 4096,
                hasher=None, interactions: int = 0,
                prefetch: bool = True, prefetch_chunks: int = 0,
                **reader_kwargs):
    """(StreamingDesign, labels, reader) from a path or an open reader —
    the coercion behind ``GLMSolver(X="train.libsvm.gz", y=None)``.

    ``hasher`` (libsvm sources) switches to the hashed feature space;
    ``prefetch_chunks`` deepens the background production queue.
    """
    reader = source if is_reader(source) \
        else open_reader(source, chunk_rows=chunk_rows, **reader_kwargs)
    kw = dict(prefetch=prefetch, prefetch_chunks=prefetch_chunks)
    if hasher is not None or interactions:
        if not hasattr(reader, "hashed_chunk_fn"):
            raise ValueError(
                f"{type(reader).__name__} does not support feature "
                "hashing; hash libsvm-style sparse sources")
        kw.update(hasher=hasher, interactions=interactions)
    design = reader.to_design(tile_size, **kw)
    return design, reader.labels(), reader
