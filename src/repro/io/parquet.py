"""Parquet/columnar reader with the same chunk contract as the libsvm
path (DESIGN.md §10).

Clickstream-style training data usually lands in columnar warehouses, not
libsvm text; this module streams a Parquet file of numeric feature
columns + a label column into ``data/pipeline.py``'s chunk-callable
contract, so ``StreamingDesign`` (and everything above it) is oblivious
to which on-disk format produced the rows.

pyarrow is an OPTIONAL dependency and the gate is fail-closed: importing
this module always succeeds (so ``repro.io`` stays importable on minimal
installs), but constructing a reader or writer without pyarrow raises an
``ImportError`` that says exactly what is missing — never a silent
degraded mode.  pyarrow-dependent tests skip when it is absent.

Reading is a buffered sequential cursor over ``ParquetFile.iter_batches``
(batches decode row-group pages lazily, so host memory stays at
O(chunk_rows · p)); a non-sequential chunk request restarts the batch
stream — correct for resume-at-cursor, and the solver's passes are
sequential anyway.  Combine with ``io.prefetch.PrefetchingSource`` to
move page decoding off the consumer thread.
"""
from __future__ import annotations

import pathlib
import threading
from typing import Optional, Sequence

import numpy as np

try:                                 # fail-closed gate: flag, not stub
    import pyarrow as _pa
    import pyarrow.parquet as _pq
    HAVE_PYARROW = True
except Exception:                    # pragma: no cover - environment gate
    _pa = _pq = None
    HAVE_PYARROW = False


def _require_pyarrow(what: str):
    if not HAVE_PYARROW:
        raise ImportError(
            f"{what} needs pyarrow, which is not installed in this "
            "environment; install pyarrow or use the libsvm reader "
            "(repro.io.libsvm) instead")


def write_parquet(path, X, y, *, label_col: str = "label",
                  feature_prefix: str = "f") -> pathlib.Path:
    """Write dense (X, y) as one Parquet file with float32 feature
    columns ``f0..f{p-1}`` and a ``label`` column (test/bench helper)."""
    _require_pyarrow("write_parquet")
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.float32)
    cols = {f"{feature_prefix}{j}": X[:, j] for j in range(X.shape[1])}
    cols[label_col] = y
    table = _pa.table(cols)
    _pq.write_table(table, str(path))
    return pathlib.Path(path)


class ParquetReader:
    """Chunked reader over one Parquet file of numeric columns.

    Args:
      path: the Parquet file.
      feature_cols: ordered feature column names; None selects every
        numeric column except ``label_col`` in schema order.
      label_col: label column name (None for unlabeled scoring data —
        ``labels()`` then raises).
      chunk_rows: rows per chunk; the final chunk is ragged per the chunk
        contract.
    """

    def __init__(self, path, *, feature_cols: Optional[Sequence[str]] = None,
                 label_col: Optional[str] = "label",
                 chunk_rows: int = 4096):
        _require_pyarrow("ParquetReader")
        if chunk_rows <= 0:
            raise ValueError("chunk_rows must be positive")
        self.path = pathlib.Path(path)
        self.chunk_rows = int(chunk_rows)
        self.label_col = label_col
        self._pf = _pq.ParquetFile(str(self.path))
        schema = self._pf.schema_arrow
        if feature_cols is None:
            feature_cols = [
                name for name, typ in zip(schema.names, schema.types)
                if name != label_col
                and (_pa.types.is_floating(typ) or _pa.types.is_integer(typ))]
        if not feature_cols:
            raise ValueError(f"{self.path} has no numeric feature columns")
        missing = [c for c in feature_cols if c not in schema.names]
        if missing:
            raise ValueError(f"{self.path} lacks columns {missing}")
        self.feature_cols = list(feature_cols)
        self.n_features = len(self.feature_cols)
        self.n_rows = int(self._pf.metadata.num_rows)
        if self.n_rows <= 0:
            raise ValueError(f"{self.path} has no rows")
        self.n_chunks = -(-self.n_rows // self.chunk_rows)
        self._lock = threading.Lock()
        self._cursor = None          # (batch iterator, next row, leftover)

    def labels(self) -> np.ndarray:
        if self.label_col is None:
            raise ValueError("reader was built with label_col=None")
        col = self._pf.read(columns=[self.label_col])[self.label_col]
        return np.asarray(col.to_numpy(zero_copy_only=False), np.float32)

    # ------------------------------------------------------------- chunks

    def _batch_to_np(self, batch) -> np.ndarray:
        out = np.empty((batch.num_rows, self.n_features), np.float32)
        for j, name in enumerate(self.feature_cols):
            out[:, j] = batch.column(j).to_numpy(zero_copy_only=False)
        return out

    def chunk_fn(self, i: int) -> np.ndarray:
        """Dense chunk ``(rows_i, n_features)`` — the chunk contract."""
        lo = i * self.chunk_rows
        rows = min(self.chunk_rows, self.n_rows - lo)
        if rows <= 0:
            raise IndexError(f"chunk {i} out of range ({self.n_chunks})")
        with self._lock:
            if self._cursor is None or self._cursor[1] != lo:
                it = self._pf.iter_batches(batch_size=self.chunk_rows,
                                           columns=self.feature_cols)
                at, buf = 0, []
                while at < lo:       # forward skip to a resume cursor
                    b = self._batch_to_np(next(it))
                    if at + len(b) > lo:
                        buf = [b[lo - at:]]
                    at += len(b)
            else:
                it, at, buf = self._cursor
                buf = list(buf)
            have = sum(len(b) for b in buf)
            while have < rows:
                b = self._batch_to_np(next(it))
                buf.append(b)
                have += len(b)
            flat = np.concatenate(buf) if len(buf) != 1 else buf[0]
            out, rest = flat[:rows], flat[rows:]
            nxt = lo + rows
            self._cursor = None if nxt >= self.n_rows else \
                (it, nxt, [rest] if len(rest) else [])
        return np.ascontiguousarray(out)

    def to_design(self, tile_size: int, *, prefetch: bool = True,
                  prefetch_chunks: int = 0):
        """``StreamingDesign`` over this file — same wiring as
        ``LibsvmReader.to_design``."""
        from repro.data.design import StreamingDesign
        fn = self.chunk_fn
        if prefetch_chunks > 0:
            from repro.io.prefetch import PrefetchingSource
            fn = PrefetchingSource(fn, self.n_chunks,
                                   depth=prefetch_chunks)
        return StreamingDesign(fn, n_rows=self.n_rows,
                               n_cols=self.n_features,
                               chunk_rows=self.chunk_rows,
                               tile_size=tile_size, prefetch=prefetch)
