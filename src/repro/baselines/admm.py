"""ADMM with sharing for feature-split L1/L2 logistic regression.

Boyd et al. 2011, sections 7.3 + 8.3.1/8.3.3, including the correction the
paper points out (footnote 3): the z̄-update quadratic coefficient is ρN/2,
not ρ/2.  The x-update LASSO is solved with Shooting (cyclic CD) as in the
paper's comparison.  Feature blocks are carried in one device tensor of
shape (M, n, p_block) and the per-block x-updates are vmapped — the sharing
structure (only Ax̄ crosses blocks) is identical to distributing over M
nodes, which is what makes this "another way to do distributed coordinate
descent" (paper §8.1).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glm as glm_lib


@dataclasses.dataclass(frozen=True)
class ADMMConfig:
    lam1: float = 0.0
    lam2: float = 0.0
    rho: float = 1.0
    n_blocks: int = 4
    shooting_passes: int = 3
    newton_iters: int = 12
    max_outer: int = 100
    family: str = "logistic"


def _shooting_pass(A, x, v, lam1_eff, lam2_eff, col_sq):
    """One cyclic CD pass on  0.5||A x - v||^2 + lam1_eff||x||_1
    + 0.5 lam2_eff ||x||^2.  Residual r = A x - v carried."""
    p = x.shape[0]

    def body(j, carry):
        x_c, r = carry
        aj = A[:, j]
        xj = x_c[j]
        rho_j = aj @ r - col_sq[j] * xj            # gradient sans own term
        num = glm_lib.soft_threshold(-rho_j, lam1_eff)
        xj_new = num / jnp.maximum(col_sq[j] + lam2_eff, 1e-30)
        r = r + aj * (xj_new - xj)
        x_c = x_c.at[j].set(xj_new)
        return x_c, r

    r0 = A @ x - v
    x, _ = jax.lax.fori_loop(0, p, body, (x, r0))
    return x


@partial(jax.jit, static_argnames=("cfg",))
def _admm_step(A_blocks, y, x_blocks, zbar, u, cfg: ADMMConfig):
    M = A_blocks.shape[0]
    fam = glm_lib.resolve_family(cfg.family)

    Ax = jnp.einsum("mnp,mp->mn", A_blocks, x_blocks)     # (M, n)
    Ax_bar = jnp.mean(Ax, axis=0)

    # ---- x-update: M independent LASSOs (vmapped "nodes")
    v = Ax + (zbar - Ax_bar - u)[None, :]
    col_sq = jnp.einsum("mnp,mnp->mp", A_blocks, A_blocks)

    def solve_block(A, x0, v_m, csq):
        def one_pass(x, _):
            return _shooting_pass(A, x, v_m, cfg.lam1 / cfg.rho,
                                  cfg.lam2 / cfg.rho, csq), None
        x, _ = jax.lax.scan(one_pass, x0, None, length=cfg.shooting_passes)
        return x

    x_new = jax.vmap(solve_block)(A_blocks, x_blocks, v, col_sq)

    # ---- z̄-update: n independent 1-D problems, Newton (ρN/2 fix applied)
    Ax_new = jnp.einsum("mnp,mp->mn", A_blocks, x_new)
    Ax_bar_new = jnp.mean(Ax_new, axis=0)
    a = Ax_bar_new + u

    def newton(z, _):
        _, s, w = fam.stats(y, M * z)            # l'(Mz) = -s, l''(Mz) = w
        grad = -M * s + M * cfg.rho * (z - a)
        hess = M * M * w + M * cfg.rho
        return z - grad / hess, None

    zbar_new, _ = jax.lax.scan(newton, zbar, None, length=cfg.newton_iters)

    u_new = u + Ax_bar_new - zbar_new

    # true objective on the consensus iterate
    margin = M * Ax_bar_new
    f = (jnp.sum(fam.stats(y, margin)[0])
         + cfg.lam1 * jnp.sum(jnp.abs(x_new))
         + 0.5 * cfg.lam2 * jnp.sum(x_new * x_new))
    nnz = jnp.sum((x_new != 0.0).astype(jnp.int32))
    return x_new, zbar_new, u_new, f, nnz


def fit_admm(X, y, cfg: ADMMConfig):
    """Returns (beta, history dict)."""
    X = np.asarray(X, np.float32)
    y = jnp.asarray(y, jnp.float32)
    n, p = X.shape
    M = cfg.n_blocks
    p_pad = p + ((-p) % M)
    Xp = np.pad(X, ((0, 0), (0, p_pad - p)))
    # (M, n, p_block) feature blocks
    A_blocks = jnp.asarray(np.stack(np.split(Xp, M, axis=1)))
    x_blocks = jnp.zeros((M, p_pad // M), jnp.float32)
    zbar = jnp.zeros((n,), jnp.float32)
    u = jnp.zeros((n,), jnp.float32)

    hist = {"f": [], "nnz": []}
    for _ in range(cfg.max_outer):
        x_blocks, zbar, u, f, nnz = _admm_step(A_blocks, y, x_blocks, zbar,
                                               u, cfg)
        # one batched device→host sync per outer iteration (SYNC001)
        fh, nnzh = jax.device_get((f, nnz))
        hist["f"].append(float(fh))
        hist["nnz"].append(int(nnzh))
    beta = np.concatenate([np.asarray(b) for b in x_blocks])[:p]
    return beta, hist
