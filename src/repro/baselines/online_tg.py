"""Distributed online learning via truncated gradient (paper §8.1).

Langford, Li & Zhang (2009) truncated-gradient updates for L1; distributed
per Agarwal et al. (2014): example-split over M shards, each shard runs a
sequential online pass, weights are averaged across shards after every pass
and used as the warmstart for the next (the paper's competing configuration
for Figs. 2-4; with lam1=0 it is the online-learning stage of the L-BFGS
combination for Figs. 5-6).

The M independent SGD chains are vmapped; the sequential pass is a
lax.scan — the JAX rendering of "M nodes run VW in parallel".
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glm as glm_lib


@dataclasses.dataclass(frozen=True)
class OnlineTGConfig:
    lam1: float = 0.0
    lam2: float = 0.0
    n_shards: int = 4
    epochs: int = 20
    lr: float = 0.25
    lr_decay_power: float = 0.6   # eta_t = lr / t^power, t = global step
    family: str = "logistic"


@partial(jax.jit, static_argnames=("cfg",))
def _epoch(X_sh, y_sh, w0, t0, cfg: OnlineTGConfig):
    """One pass of every shard (vmapped), from shared warmstart w0."""
    fam = glm_lib.resolve_family(cfg.family)

    def one_shard(Xs, ys):
        def step(carry, xy):
            w, t = carry
            x, yi = xy
            eta = cfg.lr / jnp.power(t, cfg.lr_decay_power)
            _, s, _ = fam.stats(yi, x @ w)
            w = w + eta * s * x                      # gradient step
            w = w * (1.0 - eta * cfg.lam2)           # L2 shrink
            w = glm_lib.soft_threshold(w, eta * cfg.lam1)  # truncation
            return (w, t + 1.0), None

        (w, _), _ = jax.lax.scan(step, (w0, t0), (Xs, ys))
        return w

    ws = jax.vmap(one_shard)(X_sh, y_sh)
    return jnp.mean(ws, axis=0)


def fit_online_tg(X, y, cfg: OnlineTGConfig, seed=0):
    """Returns (beta, history dict with per-epoch objective/nnz)."""
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.float32)
    n, p = X.shape
    rng = np.random.default_rng(seed)
    M = cfg.n_shards
    n_per = n // M
    perm = rng.permutation(n)[: n_per * M]
    X_sh = jnp.asarray(X[perm].reshape(M, n_per, p))
    y_sh = jnp.asarray(y[perm].reshape(M, n_per))

    fam = glm_lib.resolve_family(cfg.family)
    yj, Xj = jnp.asarray(y), jnp.asarray(X)

    @jax.jit
    def objective(w):
        return (jnp.sum(fam.stats(yj, Xj @ w)[0])
                + cfg.lam1 * jnp.sum(jnp.abs(w))
                + 0.5 * cfg.lam2 * jnp.sum(w * w))

    w = jnp.zeros((p,), jnp.float32)
    hist = {"f": [float(objective(w))], "nnz": [0]}
    t = jnp.float32(1.0)
    for ep in range(cfg.epochs):
        w = _epoch(X_sh, y_sh, w, t, cfg)
        t = t + n_per
        hist["f"].append(float(objective(w)))
        hist["nnz"].append(int(jnp.sum(jnp.abs(w) > 0)))
    return np.asarray(w), hist
