"""Competing algorithms from paper Section 8.1, reimplemented in JAX so the
benchmark figures (Figs. 2-6) compare against the same baselines the paper
used: ADMM with sharing (feature-split), online learning via truncated
gradient (example-split), and L-BFGS warmstarted by online learning."""
from repro.baselines.admm import fit_admm  # noqa: F401
from repro.baselines.online_tg import fit_online_tg  # noqa: F401
from repro.baselines.lbfgs import fit_lbfgs, fit_online_warmstart_lbfgs  # noqa: F401
