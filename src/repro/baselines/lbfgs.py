"""L-BFGS for L2-regularized GLMs + the online-warmstart combination.

Agarwal et al. (2014) Algorithm 2 — the paper's strongest L2 competitor
(Figs. 5-6): (1) average online-learning weights trained on example shards,
(2) warmstart L-BFGS from the average.  Two-loop recursion with r=15 history
pairs (the paper's default) and Armijo backtracking.  The loss/gradient are
example-separable, i.e. data-parallel at scale; this in-process version keeps
the math identical.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glm as glm_lib
from repro.baselines.online_tg import OnlineTGConfig, fit_online_tg


@dataclasses.dataclass(frozen=True)
class LBFGSConfig:
    lam2: float = 0.0
    history: int = 15          # paper's r
    max_iter: int = 100
    c1: float = 1e-4
    backtrack: float = 0.5
    max_backtracks: int = 30
    family: str = "logistic"


def fit_lbfgs(X, y, cfg: LBFGSConfig, w0=None):
    """Returns (beta, history dict)."""
    X = jnp.asarray(np.asarray(X, np.float32))
    y = jnp.asarray(np.asarray(y, np.float32))
    n, p = X.shape
    fam = glm_lib.resolve_family(cfg.family)

    @jax.jit
    def f_and_g(w):
        margins = X @ w
        loss, s, _ = fam.stats(y, margins)
        f = jnp.sum(loss) + 0.5 * cfg.lam2 * jnp.sum(w * w)
        g = -(X.T @ s) + cfg.lam2 * w
        return f, g

    w = jnp.zeros((p,), jnp.float32) if w0 is None \
        else jnp.asarray(w0, jnp.float32)
    f, g = f_and_g(w)
    S, Y, RHO = [], [], []
    hist = {"f": [float(f)], "nnz": [int(jnp.sum(jnp.abs(w) > 0))]}

    for _ in range(cfg.max_iter):
        # two-loop recursion
        q = g
        alphas = []
        for s_i, y_i, rho_i in zip(reversed(S), reversed(Y), reversed(RHO)):
            a_i = rho_i * float(s_i @ q)
            q = q - a_i * y_i
            alphas.append(a_i)
        if S:
            gamma = float(S[-1] @ Y[-1]) / max(float(Y[-1] @ Y[-1]), 1e-30)
        else:
            gamma = 1.0
        r = gamma * q
        for (s_i, y_i, rho_i), a_i in zip(zip(S, Y, RHO), reversed(alphas)):
            b_i = rho_i * float(y_i @ r)
            r = r + (a_i - b_i) * s_i
        d = -r

        gtd = float(g @ d)
        if gtd > 0:  # not a descent direction — reset memory
            S, Y, RHO = [], [], []
            d, gtd = -g, -float(g @ g)

        # Armijo backtracking
        step = 1.0
        for _bt in range(cfg.max_backtracks):
            f_new, g_new = f_and_g(w + step * d)
            if float(f_new) <= float(f) + cfg.c1 * step * gtd:
                break
            step *= cfg.backtrack
        w_new = w + step * d

        s_vec, y_vec = w_new - w, g_new - g
        sy = float(s_vec @ y_vec)
        if sy > 1e-10:
            S.append(s_vec); Y.append(y_vec); RHO.append(1.0 / sy)
            if len(S) > cfg.history:
                S.pop(0); Y.pop(0); RHO.pop(0)
        w, f, g = w_new, f_new, g_new
        hist["f"].append(float(f))
        hist["nnz"].append(int(jnp.sum(jnp.abs(w) > 0)))
        if float(jnp.max(jnp.abs(g))) < 1e-10:
            break
    return np.asarray(w), hist


def fit_online_warmstart_lbfgs(X, y, lbfgs_cfg: LBFGSConfig,
                               online_cfg: OnlineTGConfig | None = None):
    """Agarwal et al. Algorithm 2: online average → L-BFGS warmstart."""
    if online_cfg is None:
        online_cfg = OnlineTGConfig(lam1=0.0, lam2=lbfgs_cfg.lam2, epochs=2,
                                    family=lbfgs_cfg.family)
    w0, hist_online = fit_online_tg(X, y, online_cfg)
    beta, hist = fit_lbfgs(X, y, lbfgs_cfg, w0=w0)
    hist["f"] = hist_online["f"] + hist["f"]
    hist["nnz"] = hist_online["nnz"] + hist["nnz"]
    return beta, hist
