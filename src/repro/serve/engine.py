"""Batched GLM scoring over a loaded artifact (DESIGN.md §7).

The engine turns an immutable ``ServableModel`` into the serving compute
path.  Two ideas carry it:

**Active-set compaction.**  An L1-regularized model's coefficient table is
mostly zeros — that is what the penalty bought.  At construction the K
output columns are scanned once for their JOINT support A = {j : any
column has β_j ≠ 0}; the table is compacted to (A+1, K) with a trailing
all-zero row, and a (p+1,)-entry feature→slot lookup maps original feature
ids onto it (unknown / inactive / padding features → the zero row, so
scoring needs no predication anywhere).  Dense rows are sliced to the
active columns before the dot; sparse requests are remapped through the
lookup on host (O(nnz) int gather) and scored by the fused
gather-dot-link kernel (``kernels/predict_tile.py`` via
``ops.predict_tile``) in ONE device launch — gather, dot, intercept and
inverse link fused, all K outputs (several λs / several stacked models)
per launch for A/B and path-selection traffic.

**Bounded shape set.**  Every jitted program is keyed on (batch rows,
padded nnz, kind); callers that pad to a fixed bucket grid (the
micro-batcher, ``serve/batcher.py``) therefore re-jit only on the first
visit to each bucket and never in steady state.  ``compile_count`` exposes
the number of distinct compiled shapes for tests and the benchmark.

Engines are cheap to build and stateless after construction (all mutable
state is the jit cache), so one engine serves concurrent callers.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.sparse import SparseCOO
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.artifact import ServableModel


def _as_request(r):
    """Coerce one sparse request to (idx i64, val f32) arrays; a length
    mismatch is rejected here — numpy would otherwise BROADCAST a short
    value vector into every slot and score silent garbage."""
    idx, val = r
    idx = np.asarray(idx, np.int64).ravel()
    val = np.asarray(val, np.float32).ravel()
    if idx.shape != val.shape:
        raise ValueError(
            f"request feature ids and values disagree: {idx.shape} vs "
            f"{val.shape}")
    return idx, val


def coo_to_requests(X: SparseCOO):
    """Split a SparseCOO into per-row (idx, val) feature-list requests."""
    order = np.argsort(X.rows, kind="stable")
    rows, cols = X.rows[order], X.cols[order]
    vals = np.asarray(X.vals, np.float32)[order]
    starts = np.searchsorted(rows, np.arange(X.shape[0]))
    ends = np.searchsorted(rows, np.arange(X.shape[0]), side="right")
    return [(cols[s:e], vals[s:e]) for s, e in zip(starts, ends)]


class ScoringEngine:
    """Scores dense rows and sparse feature-list requests against one
    active-set-compacted weight table.

    Args:
      model: loaded ``ServableModel`` (or anything shaped like one).
      outputs: optional column subset to serve (indices into the model's K
        outputs) — e.g. the CV-selected λ plus a challenger.
      backend: kernel backend override (None = per-jax-backend default,
        "ref" = jnp oracle — the automatic fallback off-TPU).
    """

    def __init__(self, model: ServableModel, *, outputs=None, backend=None):
        self.model = model
        self.family = model.family
        W = np.asarray(model.betas, np.float32)          # (K, p)
        b0 = np.asarray(model.intercepts, np.float32)    # (K,)
        if outputs is not None:
            sel = np.atleast_1d(np.asarray(outputs, np.int64))
            W, b0 = W[sel], b0[sel]
        self.n_outputs = int(W.shape[0])
        self.n_features = int(W.shape[1])
        self._backend = backend

        # joint support across the served columns; slot p.. = zero row
        active = np.flatnonzero(np.any(W != 0.0, axis=0))
        self.active = active
        self.n_active = int(active.size)
        table = np.zeros((self.n_active + 1, self.n_outputs), np.float32)
        table[:-1] = W[:, active].T
        self._table = jnp.asarray(table)
        self._b0 = jnp.asarray(b0.reshape(1, -1))
        slot = np.full((self.n_features + 1,), self.n_active, np.int64)
        slot[active] = np.arange(self.n_active)
        self._slot = slot          # host lookup: feature id -> table row
        self._dense_fn = None
        self._packed_fns: dict = {}

    # ------------------------------------------------------------- plumbing

    @property
    def compile_count(self) -> int:
        """Number of distinct compiled sparse-scoring shapes so far — the
        batcher's bounded-bucket contract is asserted against this."""
        return len(self._packed_fns)

    def _check_kind(self, kind):
        if kind not in ("link", "response"):
            raise ValueError(f"unknown kind {kind!r}; use 'link' or "
                             "'response'")

    def map_slots(self, idx: np.ndarray) -> np.ndarray:
        """Original feature ids → compacted table rows (inactive or
        out-of-range ids → the zero row)."""
        idx = np.asarray(idx, np.int64)
        safe = np.where((idx >= 0) & (idx < self.n_features), idx,
                        self.n_features)
        return self._slot[safe]

    def pack_requests(self, requests: Sequence, nnz_pad: Optional[int] = None):
        """Pad sparse requests to one (B, J) slot/value pair of arrays.

        ``nnz_pad``: target J (≥ the max request nnz; the batcher passes a
        bucket size so the compiled-shape set stays bounded).  Slots pad
        with the zero row, values with 0 — padding scores exactly 0.
        """
        reqs = [_as_request(r) for r in requests]
        max_nnz = max((len(i) for i, _ in reqs), default=0)
        J = max(max_nnz, 1) if nnz_pad is None else int(nnz_pad)
        if max_nnz > J:
            raise ValueError(f"request nnz {max_nnz} exceeds nnz_pad {J}")
        B = len(reqs)
        slots = np.full((B, J), self.n_active, np.int32)
        vals = np.zeros((B, J), np.float32)
        for b, (idx, val) in enumerate(reqs):
            slots[b, :len(idx)] = self.map_slots(idx)
            vals[b, :len(idx)] = val
        return slots, vals

    # -------------------------------------------------------------- scoring

    def _packed_fn(self, shape, kind):
        key = (shape, kind)
        fn = self._packed_fns.get(key)
        if fn is None:
            fam, backend = self.family, self._backend

            def run(slots, vals, table, b0):
                return ops.predict_tile(slots, vals, table, b0, fam,
                                        kind=kind, backend=backend)

            fn = self._packed_fns[key] = jax.jit(run)
            # every new compiled shape is a steady-state smell: the
            # counter (and the trace instant) makes bucket leaks visible
            obs_metrics.counter("serve.compiled_shapes").inc()
            obs_trace.instant("serve/compile",
                              args={"shape": list(shape), "kind": kind})
        return fn

    def score_packed(self, slots, vals, *, kind: str = "response"):
        """Score pre-packed (B, J) slot/value arrays → (B, K) np.float32.
        THE one device launch of the sparse path; everything else routes
        here."""
        self._check_kind(kind)
        fn = self._packed_fn(tuple(slots.shape), kind)
        out = fn(jnp.asarray(slots), jnp.asarray(vals), self._table,
                 self._b0)
        return np.asarray(out)

    def score_sparse(self, requests: Sequence, *, kind: str = "response",
                     nnz_pad: Optional[int] = None, offset=None):
        """Score a batch of (idx, val) feature-list requests → (B, K).
        Without an offset the inverse link is fused into the kernel
        launch; with one, margins come back and the link applies after the
        offset."""
        self._check_kind(kind)
        slots, vals = self.pack_requests(requests, nnz_pad)
        if offset is None:
            return self.score_packed(slots, vals, kind=kind)
        return self._finish(self.score_packed(slots, vals, kind="link"),
                            kind, offset)

    def score_coo(self, X: SparseCOO, *, kind: str = "response",
                  offset=None, chunk_rows: int = 4096,
                  launch_budget: int = 1 << 22):
        """Score the rows of a SparseCOO without densifying: split into
        feature-list requests, remap to the active set, fused launches.

        Rows are processed in windows of at most ``chunk_rows``, each
        padded to ITS OWN max nnz (rounded up to a power of two so
        repeated calls reuse compiled shapes), with the window ALSO
        capped so ``rows × padded_nnz × outputs ≤ launch_budget``
        elements: a near-dense row lands in a small window of its own
        instead of widening thousands of neighbours — the memory of one
        launch (and of the oracle backend's (B, J, K) gather) stays
        bounded regardless of row-size skew, and total work stays
        O(Σ padded nnz) like the host matvec this replaces.
        """
        if X.shape[1] > self.n_features:
            raise ValueError(
                f"request has {X.shape[1]} features; model serves "
                f"{self.n_features}")
        reqs = coo_to_requests(X)
        off = None if offset is None else \
            np.asarray(offset, np.float32).reshape(-1)
        K = max(self.n_outputs, 1)

        def pow2(x):
            return 1 << max(int(x) - 1, 0).bit_length()

        outs = []
        empty = (np.zeros((0,), np.int64), np.zeros((0,), np.float32))
        s = 0
        while s < len(reqs):
            J = pow2(max(len(reqs[s][0]), 1))
            e = s + 1
            while e < len(reqs) and e - s < chunk_rows:
                J_new = max(J, pow2(max(len(reqs[e][0]), 1)))
                if (e - s + 1) * J_new * K > launch_budget:
                    break
                J = J_new
                e += 1
            n = e - s
            B = min(pow2(n), chunk_rows)
            chunk = reqs[s:e] + [empty] * (B - n)
            off_c = None
            if off is not None:
                off_c = np.zeros((B,), np.float32)
                off_c[:n] = off[s:e]
            outs.append(self.score_sparse(chunk, kind=kind, nnz_pad=J,
                                          offset=off_c)[:n])
            s = e
        if not outs:
            return np.zeros((0, self.n_outputs), np.float32)
        return np.concatenate(outs, axis=0)

    def score_dense(self, X, *, kind: str = "response", offset=None):
        """Score dense rows (n, p) → (n, K), compacted to the active
        columns before the dot (identical results to the full-β product —
        the inactive columns multiply exact zeros)."""
        self._check_kind(kind)
        X = np.asarray(X, np.float32)
        if self._dense_fn is None:
            def dense(xa, table, b0):
                # table is (A+1, K) with a zero last row; slice it off
                return xa @ table[:-1] + b0

            self._dense_fn = jax.jit(dense)
        m = np.asarray(self._dense_fn(jnp.asarray(X[:, self.active]),
                                      self._table, self._b0))
        return self._finish(m, kind, offset)

    def score(self, X, *, kind: str = "response", offset=None):
        """Polymorphic entry: SparseCOO → fused sparse path, list of
        (idx, val) requests → sparse path, array → dense path."""
        if isinstance(X, SparseCOO):
            return self.score_coo(X, kind=kind, offset=offset)
        if isinstance(X, (list, tuple)):
            return self.score_sparse(X, kind=kind, offset=offset)
        return self.score_dense(X, kind=kind, offset=offset)

    def _finish(self, m: np.ndarray, kind: str, offset):
        """Apply a per-row margin offset (broadcast over outputs), then the
        inverse link when asked for responses."""
        if offset is not None:
            m = m + np.asarray(offset, np.float32).reshape(-1, 1)
        if kind == "link":
            return m
        from repro.core import glm
        fam = glm.resolve_family(self.family)
        return np.asarray(fam.predict(jnp.asarray(m)))
