"""``repro.serve``: the production serving half of the GLM lifecycle.

Train → export → serve (DESIGN.md §7):

  * ``artifact``  — versioned on-disk model artifacts (fp32 or
    shared-scale int8) and the immutable ``ServableModel`` loader.
  * ``engine``    — active-set-compacted batched scoring for dense rows
    and sparse feature-list requests, backed by the fused
    gather-dot-link kernel (``kernels/predict_tile.py``), multi-output
    (several λs / models) per launch.
  * ``batcher``   — deadline-flushed micro-batching with a bounded
    shape-bucket set and p50/p99/rows-per-s instrumentation.

CLI: ``python -m repro.launch.serve_glm --artifact DIR --smoke``.
"""
from repro.serve.artifact import (ServableModel, artifact_bytes,
                                  dequantize_int8, export, load_artifact,
                                  quantize_int8, save_artifact)
from repro.serve.batcher import MicroBatcher
from repro.serve.engine import ScoringEngine, coo_to_requests

__all__ = [
    "ServableModel", "ScoringEngine", "MicroBatcher", "coo_to_requests",
    "save_artifact", "load_artifact", "export", "artifact_bytes",
    "quantize_int8", "dequantize_int8",
]
