"""Versioned on-disk model artifacts: the train → export → serve boundary.

An artifact is a directory with two files:

  * ``manifest.json`` — format name + schema version, family, shapes, the
    λ grid the columns were fitted at, penalty metadata, intercepts, and
    (for quantized artifacts) the shared int8 scale with its documented
    error bound.  Everything a server needs to validate and route traffic
    WITHOUT touching the weight bytes.
  * ``weights.npz`` — the (K, p) coefficient table, float32 or int8.

Schema rules (DESIGN.md §7):

  * Coefficients are stored on the ORIGINAL feature scale: the training
    session's standardization moments are already folded into
    ``GLMSolver.beta_`` / ``intercept_`` by the solver's back-transform, so
    a server never sees (and can never mis-apply) the training-time column
    scaling.  ``manifest["standardized"]`` records that the fit used
    standardization, purely as provenance.
  * K ≥ 1 output columns: a single fitted (β, b₀), a whole λ-path (one
    column per λ, for path-selection / A-B traffic), or any stack the
    exporter chooses.  ``lambdas`` aligns with the columns when known.
  * int8 quantization reuses ``sharding/compress.py``'s shared-scale
    semantics: ONE symmetric scale ``amax / 127`` for the whole table,
    deterministic round-to-nearest, so every coefficient dequantizes with
    per-element error ≤ scale/2 = amax/254, and a scored margin
    ⟨x, β̂⟩ deviates from the fp32 margin by at most (scale/2)·‖x‖₁ — the
    bound the manifest records and tests/benchmarks verify.
  * Loaders REJECT unknown format names and versions newer than they
    understand (forward-compatibility is an explicit re-export, never a
    silent reinterpretation).

``load_artifact`` returns an immutable ``ServableModel`` (arrays are
read-only); ``serve/engine.py`` builds the scoring engine from it.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional

import numpy as np

FORMAT = "repro-glm-artifact"
VERSION = 1

MANIFEST = "manifest.json"
WEIGHTS = "weights.npz"

# int8 shared-scale quantization (compress.py semantics): per-element
# dequant error is <= scale/2 with scale = max(amax, 1e-30)/127
_INT8_EPS = 1e-30


def quantize_int8(w: np.ndarray):
    """(q int8, scale) under ONE shared symmetric scale for the table.

    Same semantics as ``sharding.compress.psum_compressed(mode="int8")``:
    scale = max(|w|)/127 (floored at 1e-30 so all-zero tables round-trip to
    exactly zero), deterministic round-to-nearest, clip to ±127.  Dequant
    error is ≤ scale/2 per element.
    """
    w = np.asarray(w, np.float32)
    amax = float(np.abs(w).max()) if w.size else 0.0
    scale = max(amax, _INT8_EPS) / 127.0
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_int8(q: np.ndarray, scale: float) -> np.ndarray:
    return q.astype(np.float32) * np.float32(scale)


@dataclasses.dataclass(frozen=True)
class ServableModel:
    """An immutable, loaded artifact: everything scoring needs, nothing it
    can mutate (the arrays are read-only views)."""

    betas: np.ndarray            # (K, p) f32, ORIGINAL feature scale
    intercepts: np.ndarray       # (K,) f32
    family: str
    lambdas: Optional[np.ndarray] = None     # (K,) λ1 per column, if known
    lam2: Optional[float] = None
    penalty: Optional[dict] = None           # penalty metadata (provenance)
    standardized: bool = False
    quant: Optional[dict] = None             # {"mode","scale","amax","bound_per_l1"}
    extra: Optional[dict] = None             # frontend state (e.g. classes)
    version: int = VERSION

    def __post_init__(self):
        # freeze PRIVATE copies — never the caller's arrays, which they
        # may still legitimately mutate elsewhere
        for name in ("betas", "intercepts", "lambdas"):
            a = getattr(self, name)
            if a is not None:
                a = np.array(a)
                a.setflags(write=False)
                object.__setattr__(self, name, a)

    @property
    def n_outputs(self) -> int:
        return int(self.betas.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.betas.shape[1])

    def margin_error_bound(self, x_l1: float) -> float:
        """Worst-case |fp32 margin − dequantized margin| for a request of
        L1 mass ``x_l1``: (scale/2)·‖x‖₁ (0 for fp32 artifacts)."""
        if self.quant is None:
            return 0.0
        return 0.5 * float(self.quant["scale"]) * float(x_l1)


def _normalize_table(betas, intercepts):
    betas = np.asarray(betas, np.float32)
    if betas.ndim == 1:
        betas = betas[None, :]
    K = betas.shape[0]
    intercepts = np.zeros((K,), np.float32) if intercepts is None \
        else np.atleast_1d(np.asarray(intercepts, np.float32))
    if intercepts.shape != (K,):
        raise ValueError(
            f"intercepts must be ({K},) to match the {K} coefficient "
            f"columns; got {intercepts.shape}")
    return betas, intercepts


def save_artifact(path, *, betas, intercepts=None, family,
                  lambdas=None, lam2=None, penalty=None,
                  standardized=False, quantize=None, extra=None) -> pathlib.Path:
    """Write a versioned artifact directory; returns its path.

    ``betas`` is (p,) or (K, p) on the ORIGINAL feature scale;
    ``quantize``: None (float32) or "int8" (shared-scale table, manifest
    records the scale and the per-unit-L1 margin error bound).
    """
    from repro.core import glm as glm_lib
    fam = glm_lib.resolve_family(family)
    betas, intercepts = _normalize_table(betas, intercepts)
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)

    quant = None
    if quantize == "int8":
        q, scale = quantize_int8(betas)
        np.savez(path / WEIGHTS, betas=q)
        quant = {"mode": "int8", "scale": scale,
                 "amax": float(np.abs(betas).max()) if betas.size else 0.0,
                 # |margin_fp32 - margin_int8| <= bound_per_l1 * ||x||_1
                 "bound_per_l1": scale / 2.0}
    elif quantize is None:
        np.savez(path / WEIGHTS, betas=betas)
    else:
        raise ValueError(f"unknown quantize mode {quantize!r}; "
                         "use None or 'int8'")

    manifest = {
        "format": FORMAT,
        "version": VERSION,
        "family": fam.name,
        "n_outputs": int(betas.shape[0]),
        "n_features": int(betas.shape[1]),
        "dtype": "int8" if quant else "float32",
        "intercepts": [float(b) for b in intercepts],
        "lambdas": None if lambdas is None
        else [float(l) for l in np.atleast_1d(lambdas)],
        "lam2": None if lam2 is None else float(lam2),
        "penalty": penalty,
        "standardized": bool(standardized),
        "quant": quant,
        "extra": extra,
    }
    (path / MANIFEST).write_text(json.dumps(manifest, indent=1))
    return path


def export(model, path, *, quantize=None, path_result=None) -> pathlib.Path:
    """Export a fitted ``GLMSolver`` session or ``glm.estimators`` model.

    Duck-typed over the two frontends: a solver carries
    ``beta_``/``intercept_``/``config.family``, an estimator
    ``coef_``/``intercept_``/``family`` (plus ``classes_`` for the binary
    families, preserved in ``extra`` so a loaded classifier predicts the
    original labels).  Passing ``path_result`` (a ``PathResult``) exports
    the WHOLE λ-path as a multi-output artifact — one column per λ — for
    path-selection / A-B serving.
    """
    from repro.core import glm as glm_lib

    if hasattr(model, "coef_"):            # estimator frontend
        family = glm_lib.resolve_family(model.family).name
        beta, b0 = model.coef_, model.intercept_
        standardized = bool(getattr(model, "standardize", False))
        penalty = {"lam1": getattr(model, "lam1_", None),
                   "lam2": getattr(model, "lam2", None),
                   "penalty_factor":
                       None if getattr(model, "penalty_factor", None) is None
                       else np.asarray(model.penalty_factor).tolist()}
        lambdas = None if getattr(model, "lam1_", None) is None \
            else [model.lam1_]
        lam2 = getattr(model, "lam2", None)
    elif hasattr(model, "beta_"):          # GLMSolver session
        family = model.config.family
        beta, b0 = model.beta_, model.intercept_
        standardized = bool(getattr(model, "standardize", False))
        penalty = {"lam2": float(model.config.lam2)}
        lambdas, lam2 = None, float(model.config.lam2)
    else:
        raise TypeError(
            f"cannot export {type(model).__name__}: expected a fitted "
            "GLMSolver (beta_) or estimator (coef_)")
    if beta is None:
        raise ValueError("model is not fitted; nothing to export")

    extra = None
    classes = getattr(model, "classes_", None)
    if classes is not None:
        extra = {"classes": np.asarray(classes).tolist()}

    if path_result is not None:
        betas = path_result.betas
        intercepts = path_result.intercepts if path_result.intercepts \
            is not None else np.zeros((len(path_result.lambdas),), np.float32)
        lambdas = path_result.lambdas
        lam2 = path_result.lam2
    else:
        betas, intercepts = beta, [float(b0)]

    return save_artifact(path, betas=betas, intercepts=intercepts,
                         family=family, lambdas=lambdas, lam2=lam2,
                         penalty=penalty, standardized=standardized,
                         quantize=quantize, extra=extra)


def load_artifact(path) -> ServableModel:
    """Load an artifact directory into an immutable ``ServableModel``.

    int8 tables are dequantized to float32 ONCE here (serving compute is
    f32; int8 buys artifact size / distribution bandwidth, and the
    manifest's recorded bound is what the dequantized margins honor).
    """
    path = pathlib.Path(path)
    mf_path = path / MANIFEST
    if not mf_path.exists():
        raise FileNotFoundError(f"no {MANIFEST} under {path}; not an "
                                "artifact directory")
    manifest = json.loads(mf_path.read_text())
    if manifest.get("format") != FORMAT:
        raise ValueError(f"unknown artifact format "
                         f"{manifest.get('format')!r} (expected {FORMAT!r})")
    if int(manifest.get("version", -1)) > VERSION:
        raise ValueError(
            f"artifact version {manifest['version']} is newer than this "
            f"loader (supports <= {VERSION}); re-export or upgrade")
    with np.load(path / WEIGHTS) as z:
        betas = z["betas"]
    quant = manifest.get("quant")
    if quant is not None:
        betas = dequantize_int8(betas, quant["scale"])
    betas = np.ascontiguousarray(betas, np.float32)
    if betas.shape != (manifest["n_outputs"], manifest["n_features"]):
        raise ValueError(
            f"weight table shape {betas.shape} does not match the manifest "
            f"({manifest['n_outputs']}, {manifest['n_features']})")
    if len(manifest["intercepts"]) != manifest["n_outputs"]:
        raise ValueError(
            f"manifest carries {len(manifest['intercepts'])} intercepts "
            f"for {manifest['n_outputs']} outputs; the artifact is corrupt")
    lambdas = manifest.get("lambdas")
    return ServableModel(
        betas=betas,
        intercepts=np.asarray(manifest["intercepts"], np.float32),
        family=manifest["family"],
        lambdas=None if lambdas is None else np.asarray(lambdas, np.float64),
        lam2=manifest.get("lam2"),
        penalty=manifest.get("penalty"),
        standardized=bool(manifest.get("standardized", False)),
        quant=quant,
        extra=manifest.get("extra"),
        version=int(manifest["version"]),
    )


def artifact_bytes(path) -> int:
    """Total on-disk size of an artifact directory (size comparisons in
    benchmarks/serving_bench.py)."""
    path = pathlib.Path(path)
    return sum(f.stat().st_size for f in path.rglob("*") if f.is_file())
