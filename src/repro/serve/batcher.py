"""Micro-batching request frontend (DESIGN.md §7).

Single-row scoring is dispatch-bound: a jitted call costs a fixed launch
overhead that dwarfs the per-row FLOPs of a compacted GLM dot, so serving
each request on its own launch caps throughput at ~1/overhead regardless
of the model.  The micro-batcher amortizes it: requests queue; a flusher
coalesces the queue into ONE padded batch per engine launch, flushing when
the batch bucket fills OR the oldest request's deadline expires — the
classic throughput/latency dial.

**Shape-bucketing contract.**  A flushed batch is padded UP to the
smallest (batch-size bucket, nnz bucket) that fits, from the bounded grids
given at construction.  Every program the engine compiles is keyed on that
padded shape, so the steady-state compiled-shape set is at most
``len(batch_buckets) × len(nnz_buckets)`` per kind — nothing re-jits once
the buckets are warm (``warmup()`` pre-compiles all of them;
``engine.compile_count`` asserts the bound in tests).  A request whose nnz
exceeds the largest bucket is padded to its own nnz (a rare outsized
launch, never an error).

**Instrumentation.**  Per-request latency is measured submit → result
(the engine call goes through ``repro.timing.timed``, which blocks on the
device result — async dispatch never flatters the numbers); ``stats()``
reports p50/p99 latency, rows/s, batch occupancy and the compiled-shape
count.  The synchronous ``score_one`` path is the HONEST batch-1
baseline: one real engine dispatch per request through the same padding
machinery, exactly what a no-batching server would do
(benchmarks/serving_bench.py measures the coalescing speedup against it).
"""
from __future__ import annotations

import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.timing import percentiles, timed

DEFAULT_BATCH_BUCKETS = (1, 4, 16, 64)
DEFAULT_NNZ_BUCKETS = (8, 32, 128)


def _bucket_up(x: int, buckets) -> int:
    """Smallest bucket ≥ x; the largest bucket caps the batch dimension,
    while an outsized nnz falls through to its own size."""
    for b in buckets:
        if x <= b:
            return b
    return x


class _Pending:
    """One queued request and its completion event."""

    __slots__ = ("idx", "val", "offset", "t_submit", "event", "result",
                 "error", "t_done")

    def __init__(self, idx, val, offset):
        self.idx = idx
        self.val = val
        self.offset = offset
        self.t_submit = time.perf_counter()
        self.event = threading.Event()
        self.result = None
        self.error = None
        self.t_done = None

    def get(self, timeout: Optional[float] = None):
        if not self.event.wait(timeout):
            raise TimeoutError("request was not served before the timeout")
        if self.error is not None:
            raise self.error
        return self.result


class MicroBatcher:
    """Coalesces single-row sparse requests into bucketed engine launches.

    Args:
      engine: a ``ScoringEngine``.
      max_delay_ms: deadline — a queued request waits at most this long
        before a (possibly underfull) flush.
      batch_buckets / nnz_buckets: increasing padded-shape grids; their
        product bounds the compiled-program set (see module docstring).
      kind: "response" (inverse link, default) or "link" (raw margins).

    Use as a context manager (or call ``close()``): a background flusher
    thread drives the queue.  ``submit`` returns a handle whose ``get()``
    blocks for the (K,) output row.
    """

    def __init__(self, engine, *, max_delay_ms: float = 2.0,
                 batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
                 nnz_buckets: Sequence[int] = DEFAULT_NNZ_BUCKETS,
                 kind: str = "response"):
        if list(batch_buckets) != sorted(set(batch_buckets)) or \
                list(nnz_buckets) != sorted(set(nnz_buckets)):
            raise ValueError("buckets must be strictly increasing")
        self.engine = engine
        self.max_delay = max_delay_ms / 1e3
        self.batch_buckets = tuple(int(b) for b in batch_buckets)
        self.nnz_buckets = tuple(int(b) for b in nnz_buckets)
        self.kind = kind
        self.max_batch = self.batch_buckets[-1]

        self._lock = threading.Condition()
        self._queue: list = []
        self._closed = False
        # instrumentation (repro.obs mirrors: queue-depth gauge, flush-
        # reason counters and a request-latency histogram live in the
        # process metrics registry so multi-batcher deployments aggregate)
        self._latencies: list = []
        self._batch_sizes: list = []
        self._n_failed = 0
        self._engine_s = 0.0
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        self._m_depth = obs_metrics.gauge("serve.queue_depth")
        self._m_lat = obs_metrics.histogram("serve.latency_ms")
        self._m_flush = {r: obs_metrics.counter(f"serve.flush.{r}")
                         for r in ("full", "deadline", "close")}

        self._thread = threading.Thread(target=self._flusher, daemon=True,
                                        name="repro-serve-flusher")
        self._thread.start()

    # ------------------------------------------------------------ lifecycle

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self):
        """Flush everything still queued, then stop the flusher."""
        with self._lock:
            self._closed = True
            self._lock.notify_all()
        self._thread.join()

    # -------------------------------------------------------------- submit

    def submit(self, idx, val, *, offset: Optional[float] = None) -> _Pending:
        """Enqueue one sparse request (feature ids, values); returns a
        handle — ``handle.get()`` blocks until its flush completes.
        Malformed requests are rejected HERE, synchronously — a bad
        request must never reach (and kill) a coalesced flush that other
        callers' requests share."""
        idx = np.asarray(idx, np.int64).ravel()
        val = np.asarray(val, np.float32).ravel()
        if idx.shape != val.shape:
            raise ValueError(
                f"request feature ids and values disagree: {idx.shape} "
                f"vs {val.shape}")
        p = _Pending(idx, val, offset)
        with self._lock:
            # closed-check under the lock: a submit racing close() must
            # fail loudly, not enqueue after the final drain and hang
            if self._closed:
                raise RuntimeError("batcher is closed")
            was_empty = not self._queue
            self._queue.append(p)
            self._m_depth.set(len(self._queue))
            # wake the flusher on empty→non-empty (it sleeps untimed while
            # idle) and when a full batch is ready
            if was_empty or len(self._queue) >= self.max_batch:
                self._lock.notify_all()
        return p

    def score_one(self, idx, val, *, offset: Optional[float] = None):
        """HONEST batch-1 baseline: one real engine dispatch for this one
        request, through the same nnz bucketing — no coalescing, no
        strawman (the benchmark's reference point)."""
        nnz = _bucket_up(max(len(idx), 1), self.nnz_buckets)
        off = None if offset is None else np.asarray([offset], np.float32)
        out = self.engine.score_sparse([(idx, val)], kind=self.kind,
                                       nnz_pad=nnz, offset=off)
        return out[0]

    def warmup(self):
        """Pre-compile every (batch bucket, nnz bucket) program so steady
        state never re-jits (the bounded-bucket contract).  A
        ``kind="response"`` batcher also warms the "link" programs:
        offset-bearing requests are scored as margins first (the offset
        applies before the inverse link), and that path must not re-jit
        mid-traffic either."""
        kinds = ("link", self.kind) if self.kind != "link" else ("link",)
        for kind in kinds:
            for nb in self.nnz_buckets:
                for bb in self.batch_buckets:
                    slots = np.full((bb, nb), self.engine.n_active, np.int32)
                    vals = np.zeros((bb, nb), np.float32)
                    self.engine.score_packed(slots, vals, kind=kind)

    # ------------------------------------------------------------- flushing

    def _flusher(self):
        while True:
            with self._lock:
                # idle: sleep UNTIMED — submit()/close() wake us, so an
                # idle server burns zero CPU (no 1/max_delay polling)
                while not self._queue and not self._closed:
                    self._lock.wait()
                if not self._queue:
                    if self._closed:
                        return
                    continue
                oldest = self._queue[0].t_submit
                now = time.perf_counter()
                deadline = oldest + self.max_delay
                # wait for a full batch or the oldest request's deadline
                while (len(self._queue) < self.max_batch
                       and not self._closed and now < deadline):
                    self._lock.wait(timeout=deadline - now)
                    now = time.perf_counter()
                # why did this flush fire?  The three reasons are exactly
                # the loop's exit conditions, tested in order
                reason = "full" if len(self._queue) >= self.max_batch \
                    else ("close" if self._closed else "deadline")
                batch = self._queue[:self.max_batch]
                del self._queue[:len(batch)]
                self._m_depth.set(len(self._queue))
            self._m_flush[reason].inc()
            try:
                self._flush(batch)
            except Exception as e:          # noqa: BLE001 — must not die
                # a failed flush errors ITS handles and the server lives:
                # the error surfaces on each waiter's get(), never as a
                # dead flusher thread silently stranding future traffic
                with self._lock:
                    self._n_failed += len(batch)
                for p in batch:
                    p.error = e
                    p.event.set()

    def _flush(self, batch):
        B = _bucket_up(len(batch), self.batch_buckets)
        nnz = max((len(p.idx) for p in batch), default=1)
        J = _bucket_up(max(nnz, 1), self.nnz_buckets)
        reqs = [(p.idx, p.val) for p in batch]
        # pad the BATCH dimension with empty requests up to the bucket
        reqs += [(np.zeros((0,), np.int64), np.zeros((0,), np.float32))] \
            * (B - len(batch))
        offs = None
        if any(p.offset is not None for p in batch):
            offs = np.zeros((B,), np.float32)
            for i, p in enumerate(batch):
                offs[i] = 0.0 if p.offset is None else float(p.offset)
        with obs_trace.span("serve/flush", args={"batch": len(batch),
                                                 "B": B, "nnz": J}):
            out, dt = timed(self.engine.score_sparse, reqs, kind=self.kind,
                            nnz_pad=J, offset=offs)
        t_done = time.perf_counter()
        with self._lock:
            self._engine_s += dt
            self._batch_sizes.append(len(batch))
            if self._t_first is None:
                self._t_first = t_done - dt
            self._t_last = t_done
            for i, p in enumerate(batch):
                p.result = out[i]
                p.t_done = t_done
                lat = t_done - p.t_submit
                self._latencies.append(lat)
                self._m_lat.observe(lat * 1e3)
                p.event.set()

    # ---------------------------------------------------------------- stats

    def stats(self) -> dict:
        """p50/p99 request latency (ms), throughput and batching telemetry
        over everything served so far (quantiles via the repo's shared
        ``repro.timing.percentiles`` — no hand-rolled percentile math)."""
        with self._lock:
            lat_ms = [latency * 1e3 for latency in self._latencies]
            sizes = self._batch_sizes[:]
            wall = (self._t_last - self._t_first) \
                if self._t_last is not None else 0.0
            engine_s = self._engine_s
        n = len(lat_ms)
        pct = percentiles(lat_ms)
        return {
            "n_requests": n,
            "n_failed": self._n_failed,
            "n_batches": len(sizes),
            "mean_batch": float(np.mean(sizes)) if sizes else 0.0,
            "p50_ms": pct["p50"],
            "p99_ms": pct["p99"],
            "rows_per_s": float(n / wall) if wall > 0 else None,
            "engine_s": engine_s,
            "compiled_shapes": self.engine.compile_count,
        }
