"""Fault-tolerant checkpointing.

Design (scaled-down but shape-preserving version of a production sharded
checkpointer):

  * every checkpoint is a directory ``ckpt_<step>`` containing one ``.npz``
    per host (single-host here) plus ``manifest.json`` (step, mesh shape,
    flattened tree paths, user metadata);
  * writes are crash-atomic: a ``.tmp`` directory is populated, fsynced and
    ``os.replace``d into place — a crash mid-write never corrupts the latest
    complete checkpoint;
  * ``keep_last`` old checkpoints are garbage-collected after a successful
    commit (never before);
  * saves can run on a background thread (``async_save=True``) so the train
    loop overlaps serialization with the next step — ``wait()`` joins before
    the next save or process exit;
  * restore is **elastic**: arrays are loaded as host numpy and re-placed
    with whatever sharding the *current* mesh prescribes, so a run
    checkpointed on mesh (D₁, M₁) resumes on (D₂, M₂) (d-GLMNET state is a
    p-vector + n-vector, so feature-block remapping is a pure resharding;
    tests/test_checkpoint.py exercises 4→2 and 2→4 device moves).

At 1000+-node scale the ``.npz`` per host becomes one shard-file per
process in a parallel filesystem and the manifest commit becomes the
single-writer rendezvous — the control flow here is exactly that protocol.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key or "_root"] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory, *, keep_last: int = 3,
                 async_save: bool = False):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save

    def save(self, step: int, tree, *, metadata: Optional[dict] = None):
        """Serialize ``tree`` (pytree of arrays / scalars) at ``step``."""
        self.wait()
        # materialize on host BEFORE handing to the writer thread so the
        # caller may donate/overwrite device buffers immediately
        flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
        meta = {
            "step": int(step),
            "time": time.time(),
            "keys": sorted(flat),
            "metadata": metadata or {},
        }
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, flat, meta)

    def _write(self, step: int, flat: dict, meta: dict):
        tmp = self.dir / f"ckpt_{step}.tmp"
        final = self.dir / f"ckpt_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        np.savez(tmp / "shard_0.npz", **flat)
        (tmp / "manifest.json").write_text(json.dumps(meta))
        # fsync the directory entry then commit atomically
        fd = os.open(tmp, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(self.dir / f"ckpt_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self):
        out = []
        for p in self.dir.glob("ckpt_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue  # incomplete write — ignored by design
            try:
                out.append(int(p.name.split("_")[1]))
            except ValueError:
                pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_metadata(self, *, step: Optional[int] = None) -> dict:
        """User metadata of a checkpoint without loading its arrays —
        lets callers decide how to build the restore template (e.g. a
        single-fit vs λ-path checkpoint) before committing to ``restore``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        meta = json.loads(
            (self.dir / f"ckpt_{step}" / "manifest.json").read_text())
        return meta["metadata"]

    def restore(self, like, *, step: Optional[int] = None):
        """Restore into the structure (and shardings) of ``like``.

        ``like`` is a pytree of arrays or ShapeDtypeStructs whose shardings
        describe the CURRENT mesh — this is what makes restore elastic.
        Returns (tree, manifest_metadata).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"ckpt_{step}"
        meta = json.loads((d / "manifest.json").read_text())
        with np.load(d / "shard_0.npz") as z:
            flat = {k: z[k] for k in z.files}

        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        flat_like = _flatten(like)
        if sorted(flat_like) != meta["keys"]:
            missing = set(meta["keys"]) ^ set(flat_like)
            raise ValueError(f"checkpoint tree mismatch; differing keys: "
                             f"{sorted(missing)[:8]}")
        out = {}
        for k, ref in flat_like.items():
            arr = flat[k]
            if hasattr(ref, "sharding") and ref.sharding is not None \
                    and hasattr(ref.sharding, "mesh"):
                out[k] = jax.device_put(arr, ref.sharding)
            else:
                out[k] = jax.device_put(arr) if hasattr(ref, "shape") else arr
        # reassemble in the same order tree_flatten produced
        ordered = [out[k] for k in flat_like]
        return jax.tree_util.tree_unflatten(treedef, ordered), meta["metadata"]
