"""Fault-tolerant checkpointing.

Design (scaled-down but shape-preserving version of a production sharded
checkpointer):

  * every checkpoint is a directory ``ckpt_<step>`` containing one ``.npz``
    per host (single-host here) plus ``manifest.json`` (step, mesh shape,
    flattened tree paths, user metadata);
  * writes are crash-atomic: a ``.tmp`` directory is populated, fsynced and
    ``os.replace``d into place — a crash mid-write never corrupts the latest
    complete checkpoint;
  * ``keep_last`` old checkpoints are garbage-collected after a successful
    commit (never before);
  * saves can run on a background thread (``async_save=True``) so the train
    loop overlaps serialization with the next step — ``wait()`` joins before
    the next save, and an ``atexit`` hook (plus ``__del__``) joins any
    in-flight writer at interpreter exit, so the LAST checkpoint of a run
    is durable even when nobody calls ``wait()`` after it (writer threads
    are daemonic; without the hook a prompt exit silently dropped it);
  * restore is **elastic**: arrays are loaded as host numpy and re-placed
    with whatever sharding the *current* mesh prescribes, so a run
    checkpointed on mesh (D₁, M₁) resumes on (D₂, M₂) (d-GLMNET state is a
    p-vector + n-vector, so feature-block remapping is a pure resharding;
    tests/test_checkpoint.py exercises 4→2 and 2→4 device moves).

At 1000+-node scale the ``.npz`` per host becomes one shard-file per
process in a parallel filesystem and the manifest commit becomes the
single-writer rendezvous — the control flow here is exactly that protocol.
"""
from __future__ import annotations

import atexit
import json
import os
import pathlib
import shutil
import threading
import time
import weakref
from typing import Any, Optional

import jax
import numpy as np

from repro.dist import bootstrap as dist_boot
from repro.obs import trace as obs_trace

# Managers with potentially in-flight async writers.  One process-wide
# atexit hook joins them all: the writer threads are daemonic (a hung
# filesystem must not wedge interpreter shutdown forever), so without the
# join an exit right after the last save() dropped that checkpoint.
_LIVE_MANAGERS: "weakref.WeakSet[CheckpointManager]" = weakref.WeakSet()


@atexit.register
def _join_pending_saves():
    for mgr in list(_LIVE_MANAGERS):
        mgr.wait()


def _list_steps(directory: pathlib.Path):
    out = []
    for p in directory.glob("ckpt_*"):
        if p.suffix == ".tmp" or not (p / "manifest.json").exists():
            continue  # incomplete write — ignored by design
        try:
            out.append(int(p.name.split("_")[1]))
        except ValueError:
            pass
    return sorted(out)


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key or "_root"] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory, *, keep_last: int = 3,
                 async_save: bool = False):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        _LIVE_MANAGERS.add(self)

    def __del__(self):
        # a manager dropped mid-save still commits its last checkpoint
        try:
            self.wait()
        except Exception:
            pass

    # ------------------------------------------------------------- save

    def save(self, step: int, tree, *, metadata: Optional[dict] = None):
        """Serialize ``tree`` (pytree of arrays / scalars) at ``step``.

        Multi-process jobs (DESIGN.md §9): every process participates —
        the host materialization is a collective all-gather for arrays
        that span processes — but only the COORDINATOR touches the
        filesystem, and a process barrier orders the commit before any
        peer can race ahead to restore (or exit) against it.
        """
        self.wait()
        # materialize on host BEFORE handing to the writer thread so the
        # caller may donate/overwrite device buffers immediately
        # (gather_to_host == np.asarray for anything fully addressable)
        with obs_trace.span("ckpt/save", args={"step": int(step)}):
            flat = {k: dist_boot.gather_to_host(v)
                    for k, v in _flatten(tree).items()}
        meta = {
            "step": int(step),
            # lint: allow SYNC001 — wall-clock manifest timestamp, not a span
            "time": time.time(),
            "keys": sorted(flat),
            "metadata": metadata or {},
        }
        ctx = dist_boot.context()
        if ctx.multiprocess:
            # coordinator-only write, synchronous: async would move the
            # barrier onto the writer thread and un-order the commit
            if ctx.is_coordinator:
                self._write(self.dir, self.keep_last, step, flat, meta)
            dist_boot.barrier("ckpt-save")
            return
        if self.async_save:
            # the writer is a STATIC function over plain values: it holds no
            # reference to the manager, so a manager dropped mid-save is
            # collectable and its __del__ can join the in-flight write
            self._thread = threading.Thread(
                target=CheckpointManager._write,
                args=(self.dir, self.keep_last, step, flat, meta),
                daemon=True)
            self._thread.start()
        else:
            self._write(self.dir, self.keep_last, step, flat, meta)

    @staticmethod
    def _write(directory: pathlib.Path, keep_last: int, step: int,
               flat: dict, meta: dict):
        # the commit span runs on whichever thread writes (the async
        # writer's lane in traced runs — commit/compute overlap visible)
        with obs_trace.span("ckpt/commit", args={"step": int(step)}):
            tmp = directory / f"ckpt_{step}.tmp"
            final = directory / f"ckpt_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            np.savez(tmp / "shard_0.npz", **flat)
            (tmp / "manifest.json").write_text(json.dumps(meta))
            # fsync the directory entry then commit atomically
            fd = os.open(tmp, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
            if final.exists():
                shutil.rmtree(final)
            os.replace(tmp, final)
            CheckpointManager._gc(directory, keep_last)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @staticmethod
    def _gc(directory: pathlib.Path, keep_last: int):
        steps = sorted(_list_steps(directory))
        for s in steps[:-keep_last] if keep_last else []:
            shutil.rmtree(directory / f"ckpt_{s}", ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self):
        return _list_steps(self.dir)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_metadata(self, *, step: Optional[int] = None) -> dict:
        """User metadata of a checkpoint without loading its arrays —
        lets callers decide how to build the restore template (e.g. a
        single-fit vs λ-path checkpoint) before committing to ``restore``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        meta = json.loads(
            (self.dir / f"ckpt_{step}" / "manifest.json").read_text())
        return meta["metadata"]

    def restore(self, like, *, step: Optional[int] = None):
        """Restore into the structure (and shardings) of ``like``.

        ``like`` is a pytree of arrays or ShapeDtypeStructs whose shardings
        describe the CURRENT mesh — this is what makes restore elastic.
        Returns (tree, manifest_metadata).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"ckpt_{step}"
        with obs_trace.span("ckpt/restore", args={"step": int(step)}):
            meta = json.loads((d / "manifest.json").read_text())
            with np.load(d / "shard_0.npz") as z:
                flat = {k: z[k] for k in z.files}

        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        flat_like = _flatten(like)
        if sorted(flat_like) != meta["keys"]:
            missing = set(meta["keys"]) ^ set(flat_like)
            raise ValueError(f"checkpoint tree mismatch; differing keys: "
                             f"{sorted(missing)[:8]}")
        out = {}
        for k, ref in flat_like.items():
            arr = flat[k]
            if hasattr(ref, "sharding") and ref.sharding is not None \
                    and hasattr(ref.sharding, "mesh"):
                sh = ref.sharding
                if getattr(sh, "mesh", None) is not None and \
                        dist_boot.is_multiprocess_mesh(sh.mesh):
                    # device_put cannot target non-addressable devices;
                    # each process contributes the shards it owns
                    a = np.asarray(arr)
                    out[k] = jax.make_array_from_callback(
                        a.shape, sh, lambda idx, a=a: a[idx])
                else:
                    # restore targets this process's own addressable shards
                    # lint: allow DIST001 — single-process sharding path
                    out[k] = jax.device_put(arr, sh)
            else:
                # lint: allow DIST001 — no mesh: plain local placement
                out[k] = jax.device_put(arr) if hasattr(ref, "shape") else arr
        # reassemble in the same order tree_flatten produced
        ordered = [out[k] for k in flat_like]
        return jax.tree_util.tree_unflatten(treedef, ordered), meta["metadata"]
