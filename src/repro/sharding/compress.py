"""Compressed cross-node reductions.

The dominant collective in d-GLMNET is the AllReduce of the margin delta
``XΔβ`` (paper Algorithm 4 step 6) — O(n) floats over the ``model`` axis per
outer iteration.  The result feeds a *line search*, whose Armijo guard
rejects bad steps, which makes the margin numerically error-tolerant: a
natural target for lossy compression.

Modes:
  * ``None``  — plain f32 psum.
  * ``bf16``  — cast to bfloat16 before the psum (2x wire bytes saved).
  * ``int8``  — symmetric quantization to int8 under ONE shared scale: each
    shard's |x|-max is pmax'd over the axis, so every peer quantizes with
    the identical scale ``amax_global / 127`` and the int32-accumulated psum
    dequantizes exactly once (≈4x wire bytes saved).  A shared scale — not
    per-shard scales — is what makes the quantized values summable on the
    wire; the price is that a shard whose local amplitude is far below the
    global max loses proportionally more resolution (bounded below and in
    tests).  Deterministic round-to-nearest keeps the SPMD program
    replay-identical (stochastic rounding would need per-device rng
    plumbing; measured unnecessary at the accuracy we validate in tests).

Per-element error bound for int8: quantization error is ≤ scale/2 =
amax_global/254 per shard, so the dequantized sum over an axis of size M is
within M·amax_global/254 of the exact psum (all-zero inputs round-trip to
exactly zero — the scale floors at 1e-30, never divides by zero).

Accuracy impact is bounded by tests (fit quality deltas) and by the Armijo
rule at runtime: a corrupted direction can only shrink the accepted step,
never break the monotone descent guarantee.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def psum_compressed(x, axis: Optional[str], mode: Optional[str] = None):
    """AllReduce-sum of ``x`` over mesh axis ``axis`` with optional lossy
    wire compression. No-op reduction when ``axis`` is None."""
    if axis is None:
        return x
    if mode is None or mode == "none":
        return jax.lax.psum(x, axis)
    if mode == "bf16":
        return jax.lax.psum(x.astype(jnp.bfloat16), axis).astype(x.dtype)
    if mode == "int8":
        amax = jnp.max(jnp.abs(x))
        # shared scale: max over peers so every shard dequantizes identically
        amax = jax.lax.pmax(amax, axis)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        acc = jax.lax.psum(q.astype(jnp.int32), axis)
        return acc.astype(x.dtype) * scale
    raise ValueError(f"unknown compression mode {mode!r}")
