"""Compatibility shims over the moving jax distributed API surface.

The repo targets the modern spelling (``jax.shard_map`` with ``check_vma``,
``jax.make_mesh(..., axis_types=...)``) but must also run on older jax
releases where ``shard_map`` still lives in ``jax.experimental`` (with the
``check_rep`` keyword) and meshes have no axis types.  Every mesh/shard_map
construction in the repo goes through this module.
"""
from __future__ import annotations

import jax

try:                                    # jax >= 0.5
    _shard_map = jax.shard_map
    _MODERN = True
except AttributeError:                  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _MODERN = False

# Public flag: the legacy experimental shard_map has known autodiff gaps
# (e.g. transposing a remat'd body) that callers/tests may need to gate on.
MODERN_SHARD_MAP = _MODERN


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` returns a per-device list on older jax
    and a flat dict on newer; normalize to the dict."""
    ca = compiled.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` with the replication-check knob mapped per version."""
    if _MODERN:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=check_vma)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(AxisType.Auto,) * len(axis_names))
    except (ImportError, AttributeError, TypeError):
        return jax.make_mesh(axis_shapes, axis_names)
