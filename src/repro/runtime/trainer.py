"""LM training runtime: jitted SPMD step + fault tolerance.

Responsibilities:
  * build (params, opt_state) on the mesh (or restore from the latest
    checkpoint — crash/preemption recovery is just "run the same command");
  * drive the jitted train step over the deterministic pipeline;
  * checkpoint every ``ckpt_every`` steps (async, atomic, keep-k);
  * metrics log (loss/grad-norm/lr/step-time) as JSONL for the benchmarks.

Elasticity: because restore() re-places host arrays with the CURRENT mesh's
shardings and the pipeline is a pure function of step, a checkpoint taken on
one mesh resumes on another (tested with device-count changes in
tests/test_checkpoint.py).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.models import lm
from repro.models.common import init_params
from repro.optim import adamw


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "checkpoints"
    keep_last: int = 3
    async_save: bool = True
    log_path: Optional[str] = None
    seed: int = 0
    batch: int = 8
    seq_len: int = 128
    microbatches: int = 1


class Trainer:
    def __init__(self, arch_cfg, opt_cfg: adamw.AdamWConfig,
                 tcfg: TrainerConfig, mesh=None):
        self.cfg = arch_cfg
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.pipeline = TokenPipeline(arch_cfg.vocab_size, tcfg.batch,
                                      tcfg.seq_len, seed=tcfg.seed)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir,
                                      keep_last=tcfg.keep_last,
                                      async_save=tcfg.async_save)
        step_fn, self.model = lm.make_train_step(
            arch_cfg, opt_cfg, microbatches=tcfg.microbatches)
        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------ state

    def init_state(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(self.tcfg.seed)
        params = init_params(self.model.param_defs(), key)
        if self.mesh is not None:
            from repro.models.common import abstract_params
            sds = abstract_params(self.model.param_defs(), self.mesh,
                                  dtype=None)
            params = jax.tree.map(
                lambda x, s: jax.device_put(x, s.sharding), params, sds)
        opt_state = adamw.adamw_init(params)
        return params, opt_state, 0

    def restore_or_init(self):
        if self.ckpt.latest_step() is not None:
            params, opt_state, _ = self.init_state()
            like = {"params": params, "opt": opt_state}
            tree, md = self.ckpt.restore(like)
            return tree["params"], tree["opt"], int(md["next_step"])
        return self.init_state()

    # ------------------------------------------------------------- run

    def _put_batch(self, batch):
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        dp = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)
        sh = NamedSharding(self.mesh, P(dp, None))
        return {k: jax.device_put(v, sh) for k, v in batch.items()}

    def run(self):
        params, opt_state, start = self.restore_or_init()
        log_f = open(self.tcfg.log_path, "a") if self.tcfg.log_path else None
        losses = []
        for step in range(start, self.tcfg.steps):
            t0 = time.perf_counter()
            batch = self._put_batch(self.pipeline.batch_at(step))
            params, opt_state, metrics = self.train_step(params, opt_state,
                                                         batch)
            # one batched device→host sync per step (lint rule SYNC001);
            # it also bounds the timing span below at real compute, not
            # async dispatch
            mh = jax.device_get(metrics)
            loss = float(mh["loss"])
            losses.append(loss)
            rec = {"step": step, "loss": loss,
                   "grad_norm": float(mh["grad_norm"]),
                   "lr": float(mh["lr"]),
                   "step_s": round(time.perf_counter() - t0, 4)}
            if log_f:
                log_f.write(json.dumps(rec) + "\n")
                log_f.flush()
            if (step + 1) % self.tcfg.ckpt_every == 0 \
                    or step + 1 == self.tcfg.steps:
                self.ckpt.save(step + 1,
                               {"params": params, "opt": opt_state},
                               metadata={"next_step": step + 1,
                                         "loss": loss})
        self.ckpt.wait()
        if log_f:
            log_f.close()
        return params, opt_state, losses
