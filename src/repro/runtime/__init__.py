from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: F401
