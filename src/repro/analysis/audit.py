"""Compiled-artifact auditor: checks the invariants the AST can't see.

Where the lint rules (repro.analysis.rules) read source, this module
*traces* the registered entry points and inspects the jaxpr / launch
events / compile counters:

  * **launch structure** — the fused superstep must stay at exactly 2
    device launches (2 ``pallas_call`` eqns: stats_gram_solve +
    margin_ls), the unfused superstep at 5 logical launches (4 kernels +
    the xdb merge matvec), matching
    ``roofline.hlo.superstep_launch_targets``.  Counted two ways: ops-level
    launch events recorded at trace time (``kernels.ops.launch_trace``)
    and ``pallas_call`` primitives in the jaxpr.
  * **collective sequence** — the distributed superstep's ordered
    collective signature must be deterministic and must contain no
    collective under a ``cond`` branch (the compiled analog of lint rule
    DIST002: SPMD programs deadlock when shards disagree on whether a
    collective runs).
  * **VMEM footprint** — every traced kernel's BlockSpec-derived block
    bytes × pipeline buffers must fit the backend budget
    (``roofline.hlo.VMEM_BUDGET_BYTES``).
  * **zero steady-state recompiles** — a warm λ-path on a ``GLMSolver``
    session must trace the superstep exactly once (the PR 2 one-compile
    contract, generalizing ``serve.batcher.compile_count``).

Pure-trace: nothing here executes kernels, so the audit runs on the CPU CI
container in seconds.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dglmnet
from repro.core.dglmnet import DGLMNETConfig, FitState
from repro.kernels import ops
from repro.roofline import hlo as hlo_lib

COLLECTIVE_PRIMS = {
    "psum", "pmax", "pmin", "ppermute", "all_gather", "all_to_all",
    "reduce_scatter", "pgather", "pbroadcast",
}

# ops-level events that are one fused HBM pass in the launch model: the
# per-tile Gram accumulation feeds the tile solve without a round-trip.
_GRAM_SOLVE_EVENTS = {"tile_gram", "all_tile_grams", "cd_tile_solve"}


@dataclasses.dataclass
class AuditResult:
    name: str
    status: str          # "ok" | "fail" | "skip"
    details: dict

    def render(self) -> str:
        kv = ", ".join(f"{k}={v}" for k, v in self.details.items())
        return f"audit[{self.name}]: {self.status.upper()} ({kv})"


# --- jaxpr walking ---------------------------------------------------------


def _param_jaxprs(eqn) -> Iterator:
    for v in eqn.params.values():
        for item in (v if isinstance(v, (list, tuple)) else [v]):
            inner = getattr(item, "jaxpr", item)
            if hasattr(inner, "eqns"):
                yield inner


def iter_eqns(jaxpr) -> Iterator:
    """All equations, recursing through pjit/scan/cond/while sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _param_jaxprs(eqn):
            yield from iter_eqns(sub)


def count_primitive(jaxpr, name: str) -> int:
    return sum(1 for e in iter_eqns(jaxpr) if e.primitive.name == name)


def pallas_kernels(jaxpr) -> List[dict]:
    """(name, grid, block bytes, VMEM footprint) per traced pallas_call."""
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params.get("grid_mapping")
        nsi = eqn.params.get("name_and_src_info")
        name = getattr(nsi, "name", None) or eqn.params.get("name") \
            or "<pallas>"
        bms = list(getattr(gm, "block_mappings", ()) or ())
        block_bytes = hlo_lib.pallas_block_bytes(bms)
        out.append({
            "name": str(name).lstrip("_"),
            "grid": tuple(getattr(gm, "grid", ()) or ()),
            "block_bytes": block_bytes,
            "vmem_bytes": hlo_lib.pallas_vmem_footprint(bms),
        })
    return out


def collective_signature(jaxpr) -> List[str]:
    return [e.primitive.name for e in iter_eqns(jaxpr)
            if e.primitive.name in COLLECTIVE_PRIMS]


def collectives_under_cond(jaxpr) -> List[str]:
    """Collective primitives reachable inside a cond branch — branch
    divergence between shards turns these into deadlocks."""
    hits: List[str] = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "cond":
            continue
        for sub in _param_jaxprs(eqn):
            hits.extend(collective_signature(sub))
    return hits


def coalesce_launch_events(events: List[str]) -> List[str]:
    """Map ops-level events onto the launch-model units: adjacent Gram/
    solve events are one fused pass (``gram_solve``)."""
    units: List[str] = []
    for ev in events:
        if ev in _GRAM_SOLVE_EVENTS:
            if units and units[-1] == "gram_solve":
                continue
            units.append("gram_solve")
        else:
            units.append(ev)
    return units


# --- entry-point builders --------------------------------------------------


def _toy_args(n: int, p: int, T: int):
    st = FitState(beta=jnp.zeros((p,), jnp.float32),
                  xb=jnp.zeros((n,), jnp.float32),
                  mu=jnp.asarray(1.0, jnp.float32),
                  cursor=jnp.zeros((1,), jnp.int32),
                  step=jnp.asarray(0, jnp.int32))
    return (jnp.zeros((n, p), jnp.float32),          # X
            jnp.zeros((n,), jnp.float32),            # y
            jnp.ones((n,), jnp.float32),             # weights
            jnp.zeros((n,), jnp.float32),            # offset
            jnp.asarray([p // T], jnp.int32),        # budget
            jnp.asarray([0.1, 0.01], jnp.float32),   # lams (runtime!)
            jnp.ones((p,), jnp.float32),             # active
            jnp.ones((p,), jnp.float32),             # penf
            st)


def _build_superstep(*, fused: bool, backend: str = "pallas",
                     n: int = 8, p: int = 16, T: int = 8):
    cfg = DGLMNETConfig(lam1=0.1, lam2=0.01, tile_size=T, coupling="jacobi",
                        fuse_superstep=fused, kernel_backend=backend)
    step = dglmnet.make_superstep(cfg, n_tiles_local=p // T)
    return step, _toy_args(n, p, T)


def trace_superstep(*, fused: bool, backend: str = "pallas",
                    n: int = 8, p: int = 16, T: int = 8):
    """Returns (launch-model units, jaxpr) for one superstep trace."""
    step, args = _build_superstep(fused=fused, backend=backend, n=n, p=p,
                                  T=T)
    with ops.launch_trace() as events:
        jaxpr = jax.make_jaxpr(step)(*args)
    return coalesce_launch_events(events), jaxpr


# --- individual audits -----------------------------------------------------


def audit_superstep_launches() -> List[AuditResult]:
    """Pin the launch contract: fused = 2, unfused = 5 (DESIGN.md §8)."""
    out = []
    for fused in (True, False):
        target = hlo_lib.superstep_launch_targets(
            8, 16, 8, fused=fused)["n_launches"]
        units, jaxpr = trace_superstep(fused=fused)
        n_pallas = count_primitive(jaxpr.jaxpr, "pallas_call")
        # fused: every launch is a pallas_call.  unfused: 4 kernels + the
        # xdb merge matvec, which is a plain dot_general between launches.
        pallas_target = target if fused else target - 1
        ok = len(units) == target and n_pallas == pallas_target
        out.append(AuditResult(
            name=f"launches_{'fused' if fused else 'unfused'}",
            status="ok" if ok else "fail",
            details={"units": units, "n_units": len(units),
                     "target": target, "pallas_calls": n_pallas,
                     "pallas_target": pallas_target}))
    return out


def audit_kernel_vmem(budget_bytes: Optional[int] = None) -> AuditResult:
    """Every kernel block set (× pipeline buffers) must fit VMEM at
    production shapes (T=256 tiles, 512-row blocks)."""
    budget = budget_bytes or hlo_lib.VMEM_BUDGET_BYTES
    _, jaxpr = trace_superstep(fused=True, n=1024, p=512, T=256)
    kernels = pallas_kernels(jaxpr.jaxpr)
    over = [k for k in kernels if k["vmem_bytes"] > budget]
    return AuditResult(
        name="kernel_vmem",
        status="ok" if kernels and not over else "fail",
        details={"budget_mib": round(budget / 2 ** 20, 2),
                 "kernels": {k["name"]: round(k["vmem_bytes"] / 2 ** 20, 3)
                             for k in kernels},
                 "over_budget": [k["name"] for k in over]})


def audit_collective_sequence() -> AuditResult:
    """The sharded superstep's collective signature must be non-empty,
    deterministic across traces, and cond-free."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    n, p, T = 8, 16, 8
    cfg = DGLMNETConfig(lam1=0.1, lam2=0.01, tile_size=T, coupling="jacobi",
                        fuse_superstep=False, kernel_backend="ref")
    step = dglmnet.make_superstep(cfg, axis_data="data", axis_model="model",
                                  n_tiles_local=p // T)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    st_spec = FitState(beta=P("model"), xb=P("data"), mu=P(), cursor=P(),
                      step=P())
    in_specs = (P("data", "model"), P("data"), P("data"), P("data"),
                P(), P(), P("model"), P("model"), st_spec)
    metric_keys = ("f", "f_before", "loss", "alpha", "mu", "nnz",
                   "accepted_unit", "tiles_done")

    def traced(*args):
        state, metrics = step(*args)
        return state, metrics

    sharded = shard_map(traced, mesh=mesh, in_specs=in_specs,
                        out_specs=(st_spec, P()), check_rep=False)
    args = _toy_args(n, p, T)
    sigs = [collective_signature(jax.make_jaxpr(sharded)(*args).jaxpr)
            for _ in range(2)]
    under_cond = collectives_under_cond(
        jax.make_jaxpr(sharded)(*args).jaxpr)
    ok = bool(sigs[0]) and sigs[0] == sigs[1] and not under_cond
    return AuditResult(
        name="collective_sequence",
        status="ok" if ok else "fail",
        details={"signature": sigs[0], "deterministic": sigs[0] == sigs[1],
                 "under_cond": under_cond, "_keys": list(metric_keys)})


def audit_steady_state_recompiles() -> AuditResult:
    """A 3-λ warm path on one session must trace the superstep once: the
    λ points after the first are steady state and must add 0 traces."""
    from repro.core.solver import GLMSolver

    rng = np.random.default_rng(0)
    n, p, T = 48, 16, 8
    X = rng.normal(size=(n, p)).astype(np.float32)
    beta_true = np.zeros(p, np.float32)
    beta_true[:3] = 1.0
    y = (X @ beta_true + 0.1 * rng.normal(size=n)).astype(np.float32)
    cfg = DGLMNETConfig(family="squared", tile_size=T, max_outer=4,
                        tol=0.0)
    solver = GLMSolver(X, y, config=cfg, standardize=False,
                       fit_intercept=False)
    solver.fit(lam1=0.5, lam2=0.01)
    warm = solver.compile_count              # compiles paid on first fit
    solver.fit_path(lambdas=[0.5, 0.25, 0.1], lam2=0.01, screen=False)
    steady = solver.compile_count - warm
    return AuditResult(
        name="steady_state_recompiles",
        status="ok" if steady == 0 else "fail",
        details={"warm_compiles": warm, "steady_state_recompiles": steady,
                 "lambdas": 3})


def audit_scoring_entry_points() -> List[AuditResult]:
    """predict_tile and tile_gram stay single-launch; the streaming finish
    stage stays launch-free (selection only — no data pass)."""
    out = []

    def trace_pallas(name, fn, *args):
        with ops.launch_trace() as events:
            jaxpr = jax.make_jaxpr(fn)(*args)
        n_pallas = count_primitive(jaxpr.jaxpr, "pallas_call")
        return events, n_pallas, jaxpr

    slots = jnp.zeros((8, 128), jnp.int32)
    vals = jnp.zeros((8, 128), jnp.float32)
    table = jnp.zeros((9, 128), jnp.float32)
    b0 = jnp.zeros((128,), jnp.float32)
    ev, n_pallas, _ = trace_pallas(
        "predict_tile",
        lambda s, v, t, b: ops.predict_tile(s, v, t, b, "logistic",
                                            backend="pallas"),
        slots, vals, table, b0)
    out.append(AuditResult(
        name="predict_tile_single_launch",
        status="ok" if n_pallas == 1 and ev == ["predict_tile"] else "fail",
        details={"pallas_calls": n_pallas, "events": ev}))

    K, rb, T, nrb = 4, 8, 8, 2
    bricks = jnp.zeros((K, rb, T), jnp.float32)
    rows = jnp.zeros((K,), jnp.int32)
    n_valid = jnp.asarray(K, jnp.int32)
    w2 = jnp.ones((nrb, rb), jnp.float32)
    r2 = jnp.ones((nrb, rb), jnp.float32)
    ev, n_pallas, _ = trace_pallas(
        "tile_gram",
        lambda *a: ops.tile_gram(*a, backend="pallas"),
        bricks, rows, n_valid, w2, r2)
    out.append(AuditResult(
        name="tile_gram_single_launch",
        status="ok" if n_pallas == 1 and ev == ["tile_gram"] else "fail",
        details={"pallas_calls": n_pallas, "events": ev}))

    # streaming finish: Algorithm-3 selection over accumulated candidate
    # losses — feature-sized math only, no kernels, no design pass.
    n, p, T = 8, 16, 8
    cfg = DGLMNETConfig(lam1=0.1, lam2=0.01, tile_size=T,
                        coupling="jacobi", kernel_backend="ref")
    stream = dglmnet.make_streaming_superstep(cfg)
    st = _toy_args(n, p, T)[-1]
    lams = jnp.asarray([0.1, 0.01], jnp.float32)
    penf = jnp.ones((p,), jnp.float32)
    losses = jnp.zeros((stream.n_candidates,), jnp.float32)
    prep = {"dbeta": jnp.zeros((p,)), "cand": jnp.zeros(
                (stream.n_candidates,)),
            "loss": jnp.asarray(0.0), "f_cur": jnp.asarray(0.0),
            "R0": jnp.asarray(0.0), "grad_dot_dir": jnp.asarray(0.0),
            "quad_form": jnp.asarray(0.0),
            "tiles_done": jnp.asarray(0, jnp.int32)}
    with ops.launch_trace() as ev:
        jaxpr = jax.make_jaxpr(stream.finish)(losses, prep, st, lams, penf)
    n_pallas = count_primitive(jaxpr.jaxpr, "pallas_call")
    out.append(AuditResult(
        name="streaming_finish_launch_free",
        status="ok" if n_pallas == 0 and not ev else "fail",
        details={"pallas_calls": n_pallas, "events": list(ev)}))
    return out


# --- driver ----------------------------------------------------------------


def run_audit() -> List[AuditResult]:
    results: List[AuditResult] = []
    results.extend(audit_superstep_launches())
    results.append(audit_kernel_vmem())
    results.append(audit_collective_sequence())
    results.extend(audit_scoring_entry_points())
    results.append(audit_steady_state_recompiles())
    return results


def summary(results: List[AuditResult]) -> dict:
    return {r.name: {"status": r.status, **{
        k: v for k, v in r.details.items() if not k.startswith("_")
        and not isinstance(v, dict)}} for r in results}


def main() -> int:
    results = run_audit()
    for r in results:
        print(r.render())
    return 1 if any(r.status == "fail" for r in results) else 0


if __name__ == "__main__":
    raise SystemExit(main())
