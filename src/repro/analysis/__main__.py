"""``python -m repro.analysis`` — see lint.py for flags."""
from repro.analysis.lint import main

if __name__ == "__main__":
    raise SystemExit(main())
