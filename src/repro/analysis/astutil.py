"""Shared AST plumbing for the lint rules (repro.analysis.rules).

Everything here is pure stdlib ``ast`` — the linter must import cleanly in
environments without jax (CI containers, pre-commit hooks), so no repro or
jax imports are allowed in this module or in any rule module.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from typing import Iterator, Optional

# `# lint: allow CODE — reason` on the flagged line or the line above it
# waives one violation in place; `# noqa: CODE` is accepted as a synonym.
_WAIVER_RE = re.compile(r"#\s*(?:lint:\s*allow|noqa:?)\s+([A-Z]+\d+)")


@dataclasses.dataclass(frozen=True)
class Violation:
    code: str
    path: str          # repo-relative posix path
    line: int
    col: int
    scope: str         # enclosing qualname, e.g. "GLMSolver._run"
    message: str

    def fingerprint(self) -> tuple:
        # Line numbers churn on unrelated edits; (code, path, scope) is the
        # stable identity the baseline ratchets on.
        return (self.code, self.path, self.scope)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.code} "
                f"[{self.scope}] {self.message}")


def dotted_name(node: ast.AST) -> str:
    """'jax.device_put' for Attribute chains, 'float' for Names, '' else."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def base_name(node: ast.AST) -> Optional[str]:
    """Underlying variable of an expression: m['f'] -> m, x.item() -> x."""
    while True:
        if isinstance(node, ast.Name):
            return node.id
        if isinstance(node, (ast.Subscript, ast.Attribute, ast.Starred)):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        else:
            return None


def assigned_names(target: ast.AST) -> Iterator[str]:
    """Name ids bound by an assignment target (tuples/lists included)."""
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from assigned_names(elt)
    elif isinstance(target, ast.Starred):
        yield from assigned_names(target.value)


class FileContext:
    """One parsed source file plus the derived maps every rule needs."""

    def __init__(self, relpath: str, text: str):
        self.relpath = relpath
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=relpath)
        self.parents: dict = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self._imports = {
            node.module or ""
            for node in ast.walk(self.tree)
            if isinstance(node, ast.ImportFrom)
        } | {
            alias.name
            for node in ast.walk(self.tree)
            if isinstance(node, ast.Import)
            for alias in node.names
        }

    def imports(self, prefix: str) -> bool:
        return any(m == prefix or m.startswith(prefix + ".")
                   for m in self._imports)

    def enclosing_functions(self, node: ast.AST) -> list:
        """Innermost-first chain of enclosing FunctionDef/AsyncFunctionDef."""
        out = []
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append(cur)
            cur = self.parents.get(cur)
        return out

    def qualname(self, node: ast.AST) -> str:
        parts = []
        cur = node
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.ClassDef)):
                parts.append(cur.name)
            cur = self.parents.get(cur)
        return ".".join(reversed(parts)) or "<module>"

    def waived(self, code: str, lineno: int) -> bool:
        for ln in (lineno, lineno - 1):
            if 1 <= ln <= len(self.lines):
                for m in _WAIVER_RE.finditer(self.lines[ln - 1]):
                    if m.group(1) == code:
                        return True
        return False

    def violation(self, code: str, node: ast.AST, message: str) -> Violation:
        return Violation(code=code, path=self.relpath, line=node.lineno,
                         col=node.col_offset, scope=self.qualname(node),
                         message=message)
