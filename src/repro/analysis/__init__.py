"""repro.analysis — SPMD-safety linter + compiled-artifact auditor.

The stack's correctness rests on invariants no unit test pins directly:
collectives outside process-local control flow (DIST002), placement via
``put_global`` on spanning meshes (DIST001), λ as a runtime argument so one
compile serves a whole path (JIT001), durations via ``repro.timing``
(SYNC001), process-stable hashing in io/ (HASH001), fp32 accumulators
under bf16 matmuls (PREC001).  This package turns them into a CI gate:

* ``python -m repro.analysis --check``  — AST lint over src/repro +
  benchmarks, baseline-ratcheted (see lint.py);
* ``python -m repro.analysis --audit``  — trace-level audit: launch counts
  (fused superstep = 2), collective-sequence consistency, BlockSpec VMEM
  budgets, zero steady-state recompiles (see audit.py).

Rule docs: ``repro-lint --explain DIST002`` or DESIGN.md §11.
"""
from repro.analysis.astutil import Violation  # noqa: F401
from repro.analysis.lint import lint_paths, lint_text, main  # noqa: F401
