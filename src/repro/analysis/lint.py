"""AST lint engine: walks source trees, applies the SPMD-safety rules,
reconciles against the committed baseline, and gates CI.

Usage (also behind the ``repro-lint`` console script)::

    python -m repro.analysis                 # lint src/repro + benchmarks
    python -m repro.analysis --check         # same, exit 1 on new findings
    python -m repro.analysis --audit         # + compiled-artifact audit
    python -m repro.analysis --write-baseline  # accept current findings

The baseline (``analysis/baseline.json``) ratchets: it can only record
findings that still exist; entries carry a mandatory human ``reason`` and
fixed findings make the stale entry an error, so the debt list never grows
silently and never goes stale.  Inline waivers
(``# lint: allow CODE — reason``) are for individually-sanctioned sites.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.analysis.astutil import FileContext, Violation
from repro.analysis.rules import ALL_RULES

_HERE = pathlib.Path(__file__).resolve()
REPO_ROOT = _HERE.parents[3]
DEFAULT_BASELINE = _HERE.parent / "baseline.json"
DEFAULT_TARGETS = ("src/repro", "benchmarks")


def lint_text(text: str, relpath: str = "<memory>",
              rules: Optional[Sequence] = None) -> List[Violation]:
    """Lint one source string (the test fixtures' entry point)."""
    ctx = FileContext(relpath, text)
    out: List[Violation] = []
    for rule in (rules if rules is not None else ALL_RULES):
        for v in rule.check(ctx):
            if not ctx.waived(v.code, v.line):
                out.append(v)
    return out


def iter_py_files(targets: Iterable[pathlib.Path]):
    for t in targets:
        if t.is_file() and t.suffix == ".py":
            yield t
        elif t.is_dir():
            yield from sorted(p for p in t.rglob("*.py")
                              if "__pycache__" not in p.parts)


def lint_paths(targets: Sequence[pathlib.Path],
               rules: Optional[Sequence] = None
               ) -> Tuple[List[Violation], int]:
    """Returns (violations, n_files).  Paths render repo-relative."""
    out: List[Violation] = []
    n_files = 0
    for path in iter_py_files(targets):
        n_files += 1
        try:
            rel = path.resolve().relative_to(REPO_ROOT).as_posix()
        except ValueError:
            rel = path.as_posix()
        out.extend(lint_text(path.read_text(), rel, rules))
    out.sort(key=lambda v: (v.path, v.line, v.code))
    return out, n_files


# --- baseline ---------------------------------------------------------------


def load_baseline(path: pathlib.Path) -> dict:
    if not path.exists():
        return {"version": 1, "entries": []}
    data = json.loads(path.read_text())
    for entry in data.get("entries", []):
        if not entry.get("reason"):
            raise SystemExit(
                f"baseline entry {entry} has no `reason` — every baselined "
                "violation must say why it is allowed to stay")
    return data


def reconcile(violations: List[Violation], baseline: dict
              ) -> Tuple[List[Violation], List[Violation], List[dict]]:
    """Split into (new, baselined, stale_baseline_entries).

    An entry covers up to ``count`` findings with the same
    (code, path, scope) fingerprint.  Entries that no longer match
    anything are STALE and also fail --check: the ratchet only turns one
    way, so fixed debt must leave the ledger.
    """
    budget = {(e["code"], e["path"], e["scope"]): int(e.get("count", 1))
              for e in baseline.get("entries", [])}
    consumed = dict.fromkeys(budget, 0)
    new, old = [], []
    for v in violations:
        fp = v.fingerprint()
        if budget.get(fp, 0) > consumed.get(fp, 0):
            consumed[fp] += 1
            old.append(v)
        else:
            new.append(v)
    stale = [e for e in baseline.get("entries", [])
             if consumed[(e["code"], e["path"], e["scope"])] == 0]
    return new, old, stale


def write_baseline(path: pathlib.Path, violations: List[Violation]) -> None:
    counts: dict = {}
    for v in violations:
        counts[v.fingerprint()] = counts.get(v.fingerprint(), 0) + 1
    entries = [{"code": c, "path": p, "scope": s, "count": n,
                "reason": "TODO: justify or fix"}
               for (c, p, s), n in sorted(counts.items())]
    path.write_text(json.dumps({"version": 1, "entries": entries},
                               indent=2) + "\n")


def summary_dict(violations, new, baselined, n_files) -> dict:
    """Machine-readable roll-up (benchmarks/make_report.py renders this)."""
    per_code: dict = {}
    for v in violations:
        per_code[v.code] = per_code.get(v.code, 0) + 1
    return {"files_scanned": n_files,
            "rules": [r.CODE for r in ALL_RULES],
            "violations_total": len(violations),
            "violations_new": len(new),
            "violations_baselined": len(baselined),
            "by_code": per_code}


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="SPMD-safety linter + compiled-artifact auditor")
    ap.add_argument("paths", nargs="*",
                    help=f"files/dirs to lint (default: {DEFAULT_TARGETS})")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on any non-baselined violation or stale "
                         "baseline entry (the CI gate)")
    ap.add_argument("--audit", action="store_true",
                    help="also run the compiled-artifact auditor "
                         "(traces entry points; needs jax)")
    ap.add_argument("--baseline", type=pathlib.Path,
                    default=DEFAULT_BASELINE)
    ap.add_argument("--write-baseline", action="store_true",
                    help="record current findings as the new baseline")
    ap.add_argument("--json", type=pathlib.Path, default=None,
                    help="write the machine-readable summary here")
    ap.add_argument("--explain", metavar="CODE", default=None,
                    help="print a rule's full documentation and exit")
    args = ap.parse_args(argv)

    if args.explain:
        from repro.analysis.rules import RULES_BY_CODE
        rule = RULES_BY_CODE.get(args.explain.upper())
        if rule is None:
            print(f"unknown rule {args.explain!r}; known: "
                  f"{', '.join(sorted(RULES_BY_CODE))}", file=sys.stderr)
            return 2
        print(f"{rule.CODE} — {rule.TITLE}\n\n{rule.DOC}")
        return 0

    targets = ([pathlib.Path(p) for p in args.paths] if args.paths
               else [REPO_ROOT / t for t in DEFAULT_TARGETS])
    violations, n_files = lint_paths(targets)

    if args.write_baseline:
        write_baseline(args.baseline, violations)
        print(f"baseline: recorded {len(violations)} finding(s) -> "
              f"{args.baseline}")
        return 0

    baseline = load_baseline(args.baseline)
    new, baselined, stale = reconcile(violations, baseline)

    for v in new:
        print(v.render())
    if baselined:
        print(f"[baseline] {len(baselined)} known finding(s) suppressed")
    for e in stale:
        print(f"[stale-baseline] {e['code']} {e['path']} [{e['scope']}] no "
              "longer matches anything — remove the entry (ratchet!)")
    print(f"lint: {n_files} files, {len(violations)} finding(s), "
          f"{len(new)} new, {len(stale)} stale baseline entr"
          f"{'y' if len(stale) == 1 else 'ies'}")

    summary = summary_dict(violations, new, baselined, n_files)
    rc = 1 if (new or stale) else 0

    if args.audit:
        from repro.analysis import audit as audit_mod
        results = audit_mod.run_audit()
        summary["audit"] = audit_mod.summary(results)
        for r in results:
            print(r.render())
        if any(r.status == "fail" for r in results):
            rc = 1

    if args.json:
        args.json.parent.mkdir(parents=True, exist_ok=True)
        args.json.write_text(json.dumps(summary, indent=2) + "\n")

    if not args.check:
        return 0 if not args.audit else rc   # report-only unless gating
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
