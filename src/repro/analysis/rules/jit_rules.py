"""JIT001 — recompile hazards against the one-compile session contract.

PR 2's contract: ONE compiled superstep serves a whole λ-path — λ, fold
masks, weights, offsets and penalty factors are RUNTIME arguments.  Two
ways code re-breaks that:

* reading ``config.lam1`` / ``config.lam2`` inside a jit-traced closure
  (superstep builders, jitted functions) bakes λ into the trace, so every
  λ-grid point recompiles;
* constructing ``jax.jit(...)`` inside a loop builds a fresh closure per
  iteration, which never hits the trace cache.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import FileContext, dotted_name

# Config fields that the PR 2 contract moved to runtime arguments.
RUNTIME_ONLY_FIELDS = {"lam1", "lam2"}

_BUILDER_MARKER = "superstep"


class Jit001:
    CODE = "JIT001"
    TITLE = "trace-baked runtime arg / jit constructed per iteration"
    DOC = (
        "Inside jit-traced code (functions decorated/wrapped with jax.jit, "
        "or closures defined inside make_*superstep builders), reading "
        "config.lam1/config.lam2 bakes λ into the compiled artifact and "
        "every path point pays a re-trace — pass λ through the `lams` "
        "runtime array instead.  jax.jit(...) called inside a loop creates "
        "a fresh uncached closure per iteration."
    )

    @staticmethod
    def _is_jit_decorated(fn: ast.AST) -> bool:
        for dec in getattr(fn, "decorator_list", []):
            target = dec.func if isinstance(dec, ast.Call) else dec
            name = dotted_name(target)
            if name.endswith("jit"):
                return True
            # functools.partial(jax.jit, ...) style
            if isinstance(dec, ast.Call) and name.endswith("partial") \
                    and dec.args and dotted_name(dec.args[0]).endswith("jit"):
                return True
        return False

    def _jit_contexts(self, ctx: FileContext):
        """FunctionDefs whose body is traced: jit-decorated, or defined
        inside a superstep builder (make_superstep/make_streaming_superstep
        return closures the solver jits)."""
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if self._is_jit_decorated(fn):
                yield fn
                continue
            enclosing = ctx.enclosing_functions(fn)
            if any(_BUILDER_MARKER in e.name and e.name.startswith("make_")
                   for e in enclosing):
                yield fn

    def check(self, ctx: FileContext):
        seen: set = set()
        for fn in self._jit_contexts(ctx):
            for node in ast.walk(fn):
                if isinstance(node, ast.Attribute) \
                        and node.attr in RUNTIME_ONLY_FIELDS \
                        and id(node) not in seen:
                    seen.add(id(node))
                    yield ctx.violation(
                        self.CODE, node,
                        f"`.{node.attr}` read inside a jit-traced closure "
                        "bakes λ into the compile — the one-compile session "
                        "contract (PR 2) passes λ via the `lams` runtime "
                        "array")
        for loop in ast.walk(ctx.tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            for node in ast.walk(loop):
                if isinstance(node, ast.Call) \
                        and dotted_name(node.func).endswith("jax.jit") \
                        and id(node) not in seen:
                    seen.add(id(node))
                    yield ctx.violation(
                        self.CODE, node,
                        "jax.jit(...) constructed inside a loop — each "
                        "iteration builds a fresh closure that misses the "
                        "trace cache; hoist the jit out of the loop")
