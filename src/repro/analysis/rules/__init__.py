"""Rule registry for the repro linter.

Each rule is a class with ``CODE`` / ``TITLE`` / ``DOC`` and a
``check(ctx: FileContext) -> Iterator[Violation]`` method.  Rules are pure
stdlib-``ast`` visitors — no jax imports — so the linter runs anywhere.
Add new rules here and document them in DESIGN.md §11.
"""
from __future__ import annotations

from repro.analysis.rules.dist_rules import Dist001, Dist002
from repro.analysis.rules.hash_rules import Hash001
from repro.analysis.rules.jit_rules import Jit001
from repro.analysis.rules.obs_rules import Obs001
from repro.analysis.rules.prec_rules import Prec001
from repro.analysis.rules.sync_rules import Sync001

ALL_RULES = (Dist001(), Dist002(), Sync001(), Jit001(), Hash001(),
             Prec001(), Obs001())

RULES_BY_CODE = {r.CODE: r for r in ALL_RULES}
