"""PREC001 — bf16 matmul without an fp32 accumulator.

The mixed-precision superstep (PR 6) keeps fp32 masters and casts matmul
*inputs* to bf16; correctness rests on every such matmul pinning
``preferred_element_type=jnp.float32`` so the MXU accumulates in fp32.  A
bf16 matmul without it accumulates in bf16 (8-bit mantissa): Gram matrices
lose positive-definiteness and Armijo sums drift.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import FileContext, dotted_name

MATMUL_CALLS = {"dot", "matmul", "einsum", "tensordot", "dot_general"}


def _is_bf16_cast(node: ast.AST) -> bool:
    """x.astype(jnp.bfloat16) / x.astype('bfloat16') / asarray(..., bf16)."""
    if not isinstance(node, ast.Call):
        return False
    if isinstance(node.func, ast.Attribute) and node.func.attr == "astype":
        args = list(node.args) + [k.value for k in node.keywords]
        return any(_names_bf16(a) for a in args)
    name = dotted_name(node.func)
    if name.endswith("asarray") or name.endswith(".array"):
        args = list(node.args[1:]) + [k.value for k in node.keywords
                                      if k.arg in (None, "dtype")]
        return any(_names_bf16(a) for a in args)
    return False


def _names_bf16(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and node.value == "bfloat16":
        return True
    return dotted_name(node).endswith("bfloat16")


class Prec001:
    CODE = "PREC001"
    TITLE = "bf16 matmul operand without preferred_element_type=fp32"
    DOC = (
        "bf16 matmul inputs need preferred_element_type=jnp.float32 to "
        "keep MXU accumulation in fp32 — without it the product "
        "accumulates in bf16 and the Gram/margin sums the line search "
        "trusts are wrong at tile sizes the tests never reach.  Applies "
        "to jnp.dot/matmul/einsum/tensordot, lax.dot_general, and the "
        "`@` operator (which cannot express an accumulator type: use "
        "jnp.matmul instead when an operand is bf16)."
    )

    def check(self, ctx: FileContext):
        seen = set()   # scopes nest (module ⊃ def ⊃ def): report each once
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Module)):
                continue
            # first pass: names bound to bf16 casts in this scope
            bf16_names = set()
            for node in ast.iter_child_nodes(fn):
                for stmt in ast.walk(node):
                    if isinstance(stmt, ast.Assign) \
                            and _is_bf16_cast(stmt.value):
                        for tgt in stmt.targets:
                            if isinstance(tgt, ast.Name):
                                bf16_names.add(tgt.id)

            def is_bf16(expr):
                return _is_bf16_cast(expr) or (
                    isinstance(expr, ast.Name) and expr.id in bf16_names)

            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    name = dotted_name(node.func)
                    if name.rsplit(".", 1)[-1] not in MATMUL_CALLS:
                        continue
                    if not any(is_bf16(a) for a in node.args):
                        continue
                    kwargs = {k.arg for k in node.keywords}
                    if "preferred_element_type" not in kwargs \
                            and id(node) not in seen:
                        seen.add(id(node))
                        yield ctx.violation(
                            self.CODE, node,
                            f"{name}() with a bf16 operand but no "
                            "preferred_element_type — accumulation drops "
                            "to bf16; pin preferred_element_type="
                            "jnp.float32")
                elif isinstance(node, ast.BinOp) \
                        and isinstance(node.op, ast.MatMult):
                    if (is_bf16(node.left) or is_bf16(node.right)) \
                            and id(node) not in seen:
                        seen.add(id(node))
                        yield ctx.violation(
                            self.CODE, node,
                            "`@` with a bf16 operand cannot pin an fp32 "
                            "accumulator — use jnp.matmul(..., "
                            "preferred_element_type=jnp.float32)")
