"""HASH001 — builtin hash() in the ingestion layer.

Python's ``hash()`` for str/bytes is salted per process (PYTHONHASHSEED),
so two processes of one SPMD job disagree on every hashed feature slot —
exactly the silent cross-process divergence ``repro.io.hashing.splitmix64``
exists to prevent (PR 8's feature hashing is bit-stable across processes,
runs, and machines).
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import FileContext, dotted_name


class Hash001:
    CODE = "HASH001"
    TITLE = "builtin hash() in io/ (process-salted, breaks SPMD stability)"
    DOC = (
        "In src/repro/io/, feature/chunk identity must come from "
        "repro.io.hashing (splitmix64): builtin hash() is salted per "
        "process via PYTHONHASHSEED, so hashed slots differ between the "
        "processes of one job and between runs — weights stop lining up "
        "with features.  hashlib digests are also acceptable (stable, "
        "slower)."
    )

    def check(self, ctx: FileContext):
        p = ctx.relpath.replace("\\", "/")
        if "/io/" not in p and not p.startswith("io/"):
            return
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) \
                    and dotted_name(node.func) == "hash":
                yield ctx.violation(
                    self.CODE, node,
                    "builtin hash() is process-salted (PYTHONHASHSEED) — "
                    "use repro.io.hashing.splitmix64 for cross-process "
                    "stable feature/chunk identity")
