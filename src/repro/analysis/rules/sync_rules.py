"""SYNC001 — hidden host synchronization in hot paths.

jax dispatch is asynchronous: a superstep call returns device futures, and
the computation overlaps with Python.  Two ways code silently throws that
overlap away:

* ``time.time()`` spans around dispatch measure *enqueue* latency, not
  compute — repro.timing (``timed``/``timeit``) blocks on the result and
  uses ``perf_counter``.  A bare ``time.time()`` is only legitimate as a
  wall-clock *timestamp* (checkpoint metadata), never as a duration.
* per-iteration ``float(x)`` / ``np.asarray(x)`` / ``x.item()`` readbacks
  of device values inside a dispatch loop each force a blocking
  device→host sync.  One ``jax.device_get(metrics)`` per iteration batches
  every scalar into a single transfer (and values read from that host copy
  are free).
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import (FileContext, assigned_names, base_name,
                                    dotted_name)

SYNC_READERS = {"float", "int", "bool"}
SYNC_READER_DOTTED = {"np.asarray", "np.array", "numpy.asarray",
                      "numpy.array", "onp.asarray"}

# Callees whose results live on the host — assignments from these never
# taint their targets as device values.
HOST_PRODUCERS = {
    "jax.device_get", "device_get", "float", "int", "bool", "str", "len",
    "range", "enumerate", "zip", "list", "dict", "tuple", "set", "sorted",
    "min", "max", "sum", "abs", "round", "repr", "format", "open",
    "time.time", "time.perf_counter", "time.monotonic",
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
    "json.dumps", "json.loads", "copy.deepcopy",
}

# Method names whose call results are host values regardless of receiver
# (string/dict/file plumbing) — assignments from these don't taint.
HOST_METHOD_TAILS = {
    "partition", "rpartition", "split", "rsplit", "strip", "lstrip",
    "rstrip", "splitlines", "join", "format", "decode", "encode", "lower",
    "upper", "replace", "read", "readline", "readlines", "group", "groups",
    "items", "keys", "values", "tolist", "copy",
}


class Sync001:
    CODE = "SYNC001"
    TITLE = "hidden host sync (time.time span or per-iteration readback)"
    DOC = (
        "Durations must come from repro.timing (block_until_ready + "
        "perf_counter); time.time() around async dispatch measures enqueue "
        "latency.  Inside a loop that dispatches device work, multiple "
        "float()/np.asarray()/.item() reads of the dispatched result each "
        "block the pipe — batch them through one jax.device_get per "
        "iteration.  Waive true wall-clock timestamps with "
        "`# lint: allow SYNC001 — timestamp`."
    )

    def check(self, ctx: FileContext):
        yield from self._check_time_time(ctx)
        yield from self._check_loop_readbacks(ctx)

    def _check_time_time(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) \
                    and dotted_name(node.func) == "time.time":
                yield ctx.violation(
                    self.CODE, node,
                    "time.time() span — use time.perf_counter() or "
                    "repro.timing.timed/timeit (async dispatch makes "
                    "time.time() spans measure enqueue, not compute); "
                    "wall-clock timestamps get an inline waiver")

    def _check_loop_readbacks(self, ctx: FileContext):
        seen = set()   # loops nest; report each site cluster once
        for loop in ast.walk(ctx.tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            # names assigned inside the loop from non-host calls: these are
            # (potentially) device values whose readback blocks
            device_names: set = set()
            for stmt in ast.walk(loop):
                if isinstance(stmt, ast.Assign) \
                        and isinstance(stmt.value, ast.Call):
                    callee = dotted_name(stmt.value.func)
                    tail = callee.rsplit(".", 1)[-1]
                    if callee in HOST_PRODUCERS or tail in HOST_PRODUCERS \
                            or (isinstance(stmt.value.func, ast.Attribute)
                                and tail in HOST_METHOD_TAILS):
                        continue
                    for tgt in stmt.targets:
                        device_names.update(assigned_names(tgt))
            if not device_names:
                continue
            sites = []
            for node in ast.walk(loop):
                if not isinstance(node, ast.Call):
                    continue
                callee = dotted_name(node.func)
                if callee in SYNC_READERS or callee in SYNC_READER_DOTTED:
                    if node.args and base_name(node.args[0]) in device_names:
                        sites.append((node, callee))
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item" and not node.args:
                    if base_name(node.func.value) in device_names:
                        sites.append((node, ".item()"))
            # One sync per iteration (a convergence check) is the sanctioned
            # pattern; two or more means scalars should batch through a
            # single device_get.
            if len(sites) >= 2 and id(sites[0][0]) not in seen:
                seen.add(id(sites[0][0]))
                names = sorted({base_name(s.args[0]) if s.args
                                else base_name(s.func.value)
                                for s, _ in sites if True})
                node = sites[0][0]
                yield ctx.violation(
                    self.CODE, node,
                    f"{len(sites)} blocking host readbacks of dispatched "
                    f"values ({', '.join(n for n in names if n)}) per loop "
                    "iteration — fetch once with jax.device_get(...) and "
                    "read the host copy")
