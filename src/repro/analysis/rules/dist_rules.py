"""DIST001 / DIST002 — SPMD placement and deadlock rules.

These encode the two invariants multi-process training (repro.dist, PR 7)
actually died on during bring-up: device placement that silently works on
one process but wedges on a process-spanning mesh, and collectives gated
on process-local state so the per-process programs diverge and every peer
hangs in ``guarded_barrier`` until timeout.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import FileContext, dotted_name

# Collective / rendezvous entry points: every process in the job must
# execute these the same number of times in the same order.
COLLECTIVE_CALLS = {
    "barrier", "guarded_barrier", "wait_at_barrier",
    "kv_set", "kv_get", "gather_to_host",
    "psum", "psum_compressed", "pmean", "pmax", "pmin",
    "all_gather", "all_to_all", "ppermute",
}

# Names whose value differs per process.  Deliberately NOT included:
# ``multiprocess`` / ``num_processes`` (uniform across the job — gating on
# them is the sanctioned pattern) and plain ``rank``-free config reads.
PROCESS_LOCAL_MARKERS = {
    "process_index", "process_id", "is_coordinator", "node_id",
    "getpid", "process_count_is_me",  # defensive: any future helper
}


class Dist001:
    CODE = "DIST001"
    TITLE = "bare device placement in dist-capable module"
    DOC = (
        "Modules that can run under a process-spanning mesh must place "
        "arrays with dist.bootstrap.put_global, not jax.device_put / "
        "jnp.asarray(device=...).  A bare device_put of a host array onto "
        "a sharding whose devices span processes hangs: each process only "
        "holds its addressable shard, and the runtime waits for the rest.  "
        "put_global builds the array from per-process local shards "
        "(make_array_from_callback) and degrades to device_put only on "
        "single-process meshes.  Waive sanctioned sites (the put_global "
        "implementation itself, restores onto explicitly local devices) "
        "with `# lint: allow DIST001 — reason`."
    )

    @staticmethod
    def _dist_capable(ctx: FileContext) -> bool:
        p = ctx.relpath.replace("\\", "/")
        if "/dist/" in p or "/checkpoint/" in p:
            return True
        return ctx.imports("repro.dist") or ctx.imports("repro.dist.bootstrap")

    def check(self, ctx: FileContext):
        if not self._dist_capable(ctx):
            return
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name == "jax.device_put" or name.endswith(".device_put") \
                    or name == "device_put":
                yield ctx.violation(
                    self.CODE, node,
                    "bare jax.device_put in a dist-capable module — use "
                    "dist.bootstrap.put_global (hangs on process-spanning "
                    "meshes) or waive with a comment if the target devices "
                    "are provably process-local")
            elif name.endswith("asarray") or name.endswith(".array"):
                kw = {k.arg for k in node.keywords}
                if "device" in kw or "sharding" in kw:
                    yield ctx.violation(
                        self.CODE, node,
                        f"{name}(device=...) places on a device directly — "
                        "use dist.bootstrap.put_global for mesh placement")


class Dist002:
    CODE = "DIST002"
    TITLE = "collective reachable under process-local control flow"
    DOC = (
        "barrier/kv_set/kv_get/psum/gather_to_host must execute on every "
        "process, in the same order.  An `if ctx.is_coordinator:` (or any "
        "test derived from process_index()/host-local state) around a "
        "collective means peers wait forever — the paper's synchronous "
        "merge step deadlocks.  The sanctioned pattern: branch on "
        "process-local state for the *side effect* (write the file, print "
        "the line) and keep the collective OUTSIDE the branch, as "
        "checkpoint/manager.py does.  Early returns under process-local "
        "tests are equally fatal when a collective follows later in the "
        "same function."
    )

    @staticmethod
    def _process_local(test: ast.expr) -> bool:
        for sub in ast.walk(test):
            if isinstance(sub, (ast.Name, ast.Attribute)):
                tail = dotted_name(sub).rsplit(".", 1)[-1]
                if tail in PROCESS_LOCAL_MARKERS:
                    return True
        return False

    @staticmethod
    def _collectives_in(nodes) -> list:
        out = []
        for stmt in nodes:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Call):
                    tail = dotted_name(sub.func).rsplit(".", 1)[-1]
                    if tail in COLLECTIVE_CALLS:
                        out.append((sub, tail))
        return out

    def check(self, ctx: FileContext):
        ifs = [n for n in ast.walk(ctx.tree) if isinstance(n, ast.If)
               and self._process_local(n.test)]
        for if_node in ifs:
            # (a) a collective inside either branch of the conditional
            for call, tail in self._collectives_in(if_node.body
                                                   + if_node.orelse):
                yield ctx.violation(
                    self.CODE, call,
                    f"collective `{tail}` under a process-local "
                    "conditional — peers that don't take this branch "
                    "will hang; hoist the collective out of the branch")
            # (b) divergent early exit: the branch returns/raises, and a
            # collective appears later in the innermost enclosing function
            exits = [s for s in if_node.body
                     if isinstance(s, (ast.Return, ast.Raise,
                                       ast.Continue, ast.Break))]
            enclosing = ctx.enclosing_functions(if_node)
            if not exits or not enclosing:
                continue
            fn = enclosing[0]
            later = [s for s in ast.walk(fn)
                     if isinstance(s, ast.Call)
                     and getattr(s, "lineno", 0) > if_node.body[-1].lineno
                     and dotted_name(s.func).rsplit(".", 1)[-1]
                     in COLLECTIVE_CALLS]
            if later:
                tails = {dotted_name(s.func).rsplit(".", 1)[-1]
                         for s in later}
                yield ctx.violation(
                    self.CODE, exits[0],
                    "early exit under a process-local conditional while "
                    f"collectives ({', '.join(sorted(tails))}) follow in "
                    "the same function — exiting processes skip the "
                    "rendezvous and peers hang")
