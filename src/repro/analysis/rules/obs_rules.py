"""OBS001 — hand-rolled timing spans outside the observability layer.

The repo has exactly two sanctioned ways to time things:

* ``repro.timing`` (``timed``/``timeit``/``percentiles``) for blocking
  wall-clock measurement of jitted calls, and
* ``repro.obs.trace`` spans for structural tracing (free when disabled,
  Perfetto-exportable when enabled).

A function that pairs bare ``time.perf_counter()`` / ``time.monotonic()``
calls is re-rolling one of those: the duration it computes is invisible
to the trace, uses its own clock conventions, and (for jitted work)
usually forgets to block on the result.  OBS001 flags any function under
``src/repro`` with two or more such calls — the classic ``t0 = ...;
dt = ... - t0`` span — EXCEPT ``repro/timing.py`` and ``repro/obs/``
themselves, which are the implementations.

Legitimate remaining sites (e.g. the solver's telemetry measurement,
which must read a clock even when tracing is disabled) carry an inline
``# lint: allow OBS001 — reason`` waiver or a baseline entry.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import FileContext, dotted_name

_CLOCKS = {"time.perf_counter", "time.perf_counter_ns",
           "time.monotonic", "time.monotonic_ns"}

_EXEMPT_PREFIXES = ("src/repro/obs/",)
_EXEMPT_FILES = ("src/repro/timing.py",)


class Obs001:
    CODE = "OBS001"
    TITLE = "hand-rolled timing span (use repro.timing or repro.obs.trace)"
    DOC = (
        "Two or more bare time.perf_counter()/time.monotonic() calls in "
        "one function are a hand-rolled timing span: the duration is "
        "invisible to the obs trace and skips repro.timing's blocking "
        "convention.  Use repro.timing.timed/timeit for measurements and "
        "repro.obs.trace.span for structural tracing; waive genuinely "
        "low-level sites with `# lint: allow OBS001 — reason`."
    )

    def check(self, ctx: FileContext):
        path = ctx.relpath
        if not path.startswith("src/repro/"):
            return
        if path in _EXEMPT_FILES or \
                any(path.startswith(p) for p in _EXEMPT_PREFIXES):
            return
        # innermost-function ownership: a nested def's clock reads count
        # against the nested def, not its parent
        calls: dict = {}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) \
                    and dotted_name(node.func) in _CLOCKS:
                fns = ctx.enclosing_functions(node)
                owner = fns[0] if fns else None
                calls.setdefault(owner, []).append(node)
        for owner, sites in calls.items():
            if len(sites) < 2:
                continue          # a lone timestamp is not a span
            first = min(sites, key=lambda n: (n.lineno, n.col_offset))
            yield ctx.violation(
                self.CODE, first,
                f"{len(sites)} bare clock reads form a hand-rolled timing "
                "span — use repro.timing.timed/timeit (blocking "
                "measurement) or repro.obs.trace.span (traced span) "
                "instead")
