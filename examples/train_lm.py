"""Train a small LM (~10M params, reduced phi4 config) for a few hundred
steps with the full runtime: jitted SPMD step, deterministic resumable
pipeline, async atomic checkpoints. Kill it mid-run and re-run — it resumes.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse

import numpy as np

from repro.configs.registry import smoke_variant
from repro.optim import adamw
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = smoke_variant("phi4-mini-3.8b").replace(
        n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=2048)
    trainer = Trainer(
        cfg,
        adamw.AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps),
        TrainerConfig(steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir, batch=8, seq_len=128,
                      log_path="/tmp/repro_lm_train.jsonl"))
    _, _, losses = trainer.run()
    print(f"loss: first10={np.mean(losses[:10]):.4f} "
          f"last10={np.mean(losses[-10:]):.4f}  "
          f"({len(losses)} steps this run)")


if __name__ == "__main__":
    main()
