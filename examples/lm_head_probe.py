"""The paper's technique meeting the LM zoo: extract frozen features from a
(reduced) gemma3 backbone and fit an elastic-net GLM readout with d-GLMNET —
the classifier-head / calibration workload the paper targets, fed by LM
embeddings (DESIGN.md §Arch-applicability).

    PYTHONPATH=src python examples/lm_head_probe.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.registry import smoke_variant
from repro.core import head_probe
from repro.core.dglmnet import DGLMNETConfig
from repro.data import synthetic
from repro.models import lm
from repro.models.common import init_params


def main():
    cfg = smoke_variant("gemma3-12b")
    model = lm.build_model(cfg)
    params = init_params(model.param_defs(), jax.random.PRNGKey(0))

    # synthesize a 2-class token-sequence task: class-conditional unigram
    rng = np.random.default_rng(0)
    n, S = 512, 32
    labels = rng.choice([-1.0, 1.0], n)
    tokens = np.where((labels[:, None] > 0),
                      rng.integers(0, cfg.vocab_size // 2, (n, S)),
                      rng.integers(cfg.vocab_size // 2, cfg.vocab_size,
                                   (n, S))).astype(np.int32)

    @jax.jit
    def features_of(tok):
        h, _ = model.forward(params, tok, mode="train", return_hidden=True)
        return jnp.mean(h, axis=1)

    feats = np.concatenate([np.asarray(features_of(jnp.asarray(t)))
                            for t in np.split(tokens, 8)])
    print(f"extracted features: {feats.shape} from frozen "
          f"{cfg.name}-smoke backbone")

    n_tr = 400
    cfg_glm = DGLMNETConfig(lam1=0.05, lam2=0.05, tile_size=16, max_outer=40)
    res = head_probe.fit_probe(feats[:n_tr], labels[:n_tr], cfg_glm)
    p = np.asarray(head_probe.predict_proba(feats[n_tr:], res.beta))
    acc = ((p > 0.5) == (labels[n_tr:] > 0)).mean()
    au = synthetic.au_prc(labels[n_tr:], p)
    print(f"probe: {res.n_iter} d-GLMNET iterations, "
          f"nnz={(res.beta != 0).sum()}/{len(res.beta)}")
    print(f"held-out accuracy: {acc:.3f}   auPRC: {au:.3f}")


if __name__ == "__main__":
    main()
