"""Quickstart: a GLMSolver session — fit an elastic-net logistic regression
with d-GLMNET on one device, compare against the FISTA oracle, then reuse
the same session (design packed + superstep compiled once) for a
warm-started regularization path.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import glm, prox_ref
from repro.core.dglmnet import DGLMNETConfig
from repro.core.solver import GLMSolver
from repro.data import synthetic

import jax.numpy as jnp


def main():
    ds = synthetic.make_dense(n=2000, p=200, k_true=25, seed=0)
    lam1, lam2 = 1.0, 0.5

    solver = GLMSolver(ds.train.X, ds.train.y, family="logistic",
                       config=DGLMNETConfig(tile_size=64, max_outer=60,
                                            tol=1e-10))
    res = solver.fit(lam1=lam1, lam2=lam2, verbose=True)

    _, hist = prox_ref.fit_fista(ds.train.X, ds.train.y, lam1=lam1,
                                 lam2=lam2, max_iter=3000)
    f_d = float(glm.objective(glm.LOGISTIC, jnp.asarray(ds.train.y),
                              jnp.asarray(ds.train.X),
                              jnp.asarray(res.beta), lam1, lam2))
    print(f"\nd-GLMNET objective : {f_d:.6f}  ({res.n_iter} iterations)")
    print(f"FISTA oracle       : {hist[-1]:.6f}")
    print(f"nnz(beta)          : {(res.beta != 0).sum()} / {len(res.beta)}")

    acc = solver.score(ds.test.X, ds.test.y)
    au = synthetic.au_prc(ds.test.y, solver.predict(ds.test.X, kind="link"))
    print(f"test accuracy      : {acc:.3f}   auPRC: {au:.3f}")

    # the same session fits a whole warm-started path — the superstep is
    # NOT recompiled (λ is a runtime argument)
    path = solver.fit_path(n_lambdas=30, lam_ratio=1e-3, lam2=lam2)
    print(f"\n30-point λ-path    : λ_max={path.lambdas[0]:.3f} → "
          f"{path.lambdas[-1]:.4f}, nnz {path.nnz[0]} → {path.nnz[-1]}, "
          f"{path.n_iters.sum()} supersteps total, "
          f"{solver.compile_count} superstep compile(s)")

    # estimator frontend: λ1 by mask-based 5-fold CV (folds are runtime row
    # masks on the same compiled superstep — still zero recompiles)
    from repro.glm import LogisticRegressionCD
    clf = LogisticRegressionCD(lam1=None, cv=5, n_lambdas=20,
                               tile_size=64, max_outer=60)
    clf.fit(ds.train.X, (ds.train.y > 0).astype(int))
    print(f"\nCV-selected λ1     : {clf.lam1_:.4f} "
          f"(interior index {clf.cv_result_.best_index}/"
          f"{len(clf.cv_result_.lambdas)})")
    print(f"estimator accuracy : "
          f"{clf.score(ds.test.X, (ds.test.y > 0).astype(int)):.3f}  "
          f"intercept={clf.intercept_:.3f}")


if __name__ == "__main__":
    main()
