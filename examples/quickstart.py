"""Quickstart: fit an elastic-net logistic regression with d-GLMNET on one
device and compare against the FISTA oracle.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import dglmnet, glm, prox_ref
from repro.core.dglmnet import DGLMNETConfig
from repro.data import synthetic

import jax.numpy as jnp


def main():
    ds = synthetic.make_dense(n=2000, p=200, k_true=25, seed=0)
    lam1, lam2 = 1.0, 0.5

    cfg = DGLMNETConfig(family="logistic", lam1=lam1, lam2=lam2,
                        tile_size=64, max_outer=60, tol=1e-10)
    res = dglmnet.fit(ds.train.X, ds.train.y, cfg, verbose=True)

    _, hist = prox_ref.fit_fista(ds.train.X, ds.train.y, lam1=lam1,
                                 lam2=lam2, max_iter=3000)
    f_d = float(glm.objective(glm.LOGISTIC, jnp.asarray(ds.train.y),
                              jnp.asarray(ds.train.X),
                              jnp.asarray(res.beta), lam1, lam2))
    print(f"\nd-GLMNET objective : {f_d:.6f}  ({res.n_iter} iterations)")
    print(f"FISTA oracle       : {hist[-1]:.6f}")
    print(f"nnz(beta)          : {(res.beta != 0).sum()} / {len(res.beta)}")

    scores = ds.test.X @ res.beta
    acc = ((scores > 0) == (ds.test.y > 0)).mean()
    au = synthetic.au_prc(ds.test.y, scores)
    print(f"test accuracy      : {acc:.3f}   auPRC: {au:.3f}")


if __name__ == "__main__":
    main()
