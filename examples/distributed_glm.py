"""Distributed d-GLMNET on 8 (simulated) nodes via GLMSolver sessions: the
paper's 1-D feature split, the 2-D extension, ALB straggler mitigation,
margin compression — all converging to the same optimum — plus a
warm-started λ-path on the 2-D session.

    python examples/distributed_glm.py       (sets up fake devices itself)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import glm
from repro.core.dglmnet import DGLMNETConfig
from repro.core.solver import GLMSolver
from repro.data import synthetic
from repro.data.design import brick_occupancy
from repro.sharding import compat


def main():
    ds = synthetic.make_sparse(n=4000, p=8000, avg_nnz=50, seed=3)
    X, y = ds.train.X, ds.train.y       # SparseCOO — never densified
    occ = brick_occupancy(X, 128)
    print(f"sparse design: nnz={X.nnz}, brick occupancy={occ:.2f}")

    base = DGLMNETConfig(lam1=1.0, lam2=0.1, tile_size=128,
                         coupling="jacobi", max_outer=40, tol=1e-10)

    def obj(beta):
        return float(glm.negloglik(glm.LOGISTIC, jnp.asarray(y),
                                   jnp.asarray(X.matvec(beta)))
                     + glm.penalty(jnp.asarray(beta), base.lam1, base.lam2))

    # the paper's layout: 8 feature blocks, every node holds all rows
    mesh_1d = compat.make_mesh((1, 8), ("data", "model"))
    res = GLMSolver(X, y, config=base, mesh=mesh_1d).fit()
    print(f"1-D (paper) split : f={obj(res.beta):.5f} "
          f"iters={res.n_iter} nnz={(res.beta != 0).sum()}")

    # 2-D: rows × features (beyond-paper scale-out); the session is kept —
    # its packed design and compiled superstep serve every later fit
    mesh_2d = compat.make_mesh((2, 4), ("data", "model"))
    solver_2d = GLMSolver(X, y, config=base, mesh=mesh_2d)
    res = solver_2d.fit()
    print(f"2-D rows×features : f={obj(res.beta):.5f} iters={res.n_iter}")

    # ALB with a straggling node (paper Section 7)
    alb = dataclasses.replace(base, alb=True)
    res = GLMSolver(X, y, config=alb, mesh=mesh_1d,
                    speeds=np.array([1, 1, 1, 0.2, 1, 1, 2, 1])).fit()
    print(f"ALB w/ straggler  : f={obj(res.beta):.5f} iters={res.n_iter}")

    # compressed margin allreduce
    comp = dataclasses.replace(base, compress_margin="bf16")
    res = GLMSolver(X, y, config=comp, mesh=mesh_2d).fit()
    print(f"bf16 margin comm  : f={obj(res.beta):.5f} iters={res.n_iter}")

    # warm-started λ-path on the existing 2-D session: one superstep
    # compile serves the whole grid (λ is a runtime argument)
    path = solver_2d.fit_path(n_lambdas=10, lam_ratio=1e-2, lam2=base.lam2)
    print(f"10-λ path (2-D)   : nnz {path.nnz[0]} → {path.nnz[-1]}, "
          f"{path.n_iters.sum()} supersteps, "
          f"{solver_2d.compile_count} compile(s)")


if __name__ == "__main__":
    main()
