"""Distributed d-GLMNET on 8 (simulated) nodes: the paper's 1-D feature
split, the 2-D extension, ALB straggler mitigation, and margin compression —
all converging to the same optimum.

    python examples/distributed_glm.py       (sets up fake devices itself)
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dglmnet, glm
from repro.core.dglmnet import DGLMNETConfig
from repro.data import synthetic
from repro.data.design import brick_occupancy
from repro.sharding import compat


def main():
    ds = synthetic.make_sparse(n=4000, p=8000, avg_nnz=50, seed=3)
    X, y = ds.train.X, ds.train.y       # SparseCOO — never densified
    occ = brick_occupancy(X, 128)
    print(f"sparse design: nnz={X.nnz}, brick occupancy={occ:.2f}")

    base = DGLMNETConfig(lam1=1.0, lam2=0.1, tile_size=128,
                         coupling="jacobi", max_outer=40, tol=1e-10)

    def obj(beta):
        return float(glm.negloglik(glm.LOGISTIC, jnp.asarray(y),
                                   jnp.asarray(X.matvec(beta)))
                     + glm.penalty(jnp.asarray(beta), base.lam1, base.lam2))

    # the paper's layout: 8 feature blocks, every node holds all rows
    mesh_1d = compat.make_mesh((1, 8), ("data", "model"))
    res = dglmnet.fit_sharded(X, y, base, mesh_1d, verbose=False)
    print(f"1-D (paper) split : f={obj(res.beta):.5f} "
          f"iters={res.n_iter} nnz={(res.beta != 0).sum()}")

    # 2-D: rows × features (beyond-paper scale-out)
    mesh_2d = compat.make_mesh((2, 4), ("data", "model"))
    res = dglmnet.fit_sharded(X, y, base, mesh_2d)
    print(f"2-D rows×features : f={obj(res.beta):.5f} iters={res.n_iter}")

    # ALB with a straggling node (paper Section 7)
    alb = dataclasses.replace(base, alb=True)
    res = dglmnet.fit_sharded(X, y, alb, mesh_1d,
                              speeds=np.array([1, 1, 1, 0.2, 1, 1, 2, 1]))
    print(f"ALB w/ straggler  : f={obj(res.beta):.5f} iters={res.n_iter}")

    # compressed margin allreduce
    comp = dataclasses.replace(base, compress_margin="bf16")
    res = dglmnet.fit_sharded(X, y, comp, mesh_2d)
    print(f"bf16 margin comm  : f={obj(res.beta):.5f} iters={res.n_iter}")


if __name__ == "__main__":
    main()
