"""End-to-end driver (the paper's kind of workload at the largest size this
CPU box sustains): train a wide sparse logistic regression — ~1M features —
with distributed d-GLMNET over 8 simulated feature-split nodes, with
checkpointing every 10 supersteps and automatic resume.

Scale knobs: N_EXAMPLES / N_FEATURES / devices; the same driver lowered on
the (16,16) and (2,16,16) production meshes is results/dryrun/*/dglmnet__*.

    python examples/train_glm_large.py [--features 1048576] [--steps 200]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import pathlib
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.dglmnet import DGLMNETConfig
from repro.core.solver import GLMSolver
from repro.data import synthetic
from repro.sharding import compat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--examples", type=int, default=10_000)
    ap.add_argument("--features", type=int, default=1 << 16,
                    help="feature count (default 65k; raise to 1<<20 with "
                         "enough RAM — the algorithm/IO path is identical)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/dglmnet_large_ckpt")
    args = ap.parse_args()

    print(f"generating sparse data: n={args.examples} p={args.features}")
    ds = synthetic.make_sparse(n=args.examples, p=args.features,
                               avg_nnz=40, k_true=500, seed=11)
    X = ds.train.X                      # SparseCOO — the dense (n, p) matrix
    print(f"nnz={X.nnz/1e6:.1f}M")      # is never materialized on host

    mesh = compat.make_mesh((1, 8), ("data", "model"))
    cfg = DGLMNETConfig(lam1=2.0, lam2=0.1, tile_size=256,
                        coupling="jacobi", alb=True,
                        max_outer=args.steps, tol=1e-9)
    mgr = CheckpointManager(args.ckpt_dir, keep_last=2, async_save=True)
    if mgr.latest_step():
        print(f"resuming from superstep {mgr.latest_step()}")

    t0 = time.time()
    solver = GLMSolver(X, ds.train.y, config=cfg, mesh=mesh)
    res = solver.fit(ckpt_manager=mgr, ckpt_every=10, verbose=True)
    dt = time.time() - t0
    print(f"\ndone in {dt:.1f}s  ({res.n_iter} supersteps, "
          f"converged={res.converged})")
    print(f"nnz={(res.beta != 0).sum()} of {len(res.beta)}")
    # beta comes back in the original feature order
    scores = ds.test.X.matvec(res.beta)
    print(f"test auPRC = {synthetic.au_prc(ds.test.y, scores):.4f}")


if __name__ == "__main__":
    main()
